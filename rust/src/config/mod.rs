//! Experiment configuration: typed config struct, `key = value` config-file
//! parser, and the CLI argument parser (no `clap` in the offline vendor set).

pub mod cli;

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::quant::Scheme;

/// Default influence-scan memory budget (MiB). Shared by [`Config`] and
/// `influence::ScoreOpts` so the CLI and library paths shard identically
/// (defined once in `qless-core`, the bottom of the workspace).
pub use qless_core::DEFAULT_MEM_BUDGET_MB;

/// Everything an end-to-end QLESS run needs. Field names double as config
/// file keys (`key = value`, `#` comments) and `--key value` CLI overrides
/// (underscores and dashes are interchangeable).
#[derive(Debug, Clone)]
pub struct Config {
    /// Model size preset: tiny | small | base (must exist in the manifest).
    pub model: String,
    /// Artifact directory produced by `make artifacts`.
    pub artifacts: String,
    /// Output directory for checkpoints / datastores / reports.
    pub run_dir: String,
    /// Corpus size (total samples across the 4 sources, paper ≈ 270K).
    pub corpus_size: usize,
    /// Random seed governing corpus, warmup subset, projection, selection.
    pub seed: u64,
    /// Warmup subset fraction (paper: 0.05).
    pub warmup_frac: f64,
    /// Warmup epochs == number of checkpoints N (paper: 4).
    pub warmup_epochs: usize,
    /// Selection fraction (paper main: 0.05).
    pub select_frac: f64,
    /// Fine-tune epochs on the selected subset (paper: 4).
    pub finetune_epochs: usize,
    /// Peak learning rate (paper: 2e-5 on 7B; scaled up for SimLM).
    pub lr: f64,
    /// LR warmup fraction of total steps (paper: linear warmup 3%).
    pub lr_warmup_frac: f64,
    /// Gradient quantization bits: 16 (LESS) | 8 | 4 | 2 | 1.
    pub bits: u8,
    /// Multi-precision build list (`--bits 1,2,4,8,16`): every listed
    /// precision is written in ONE extraction pass by the streaming
    /// builder. Empty = build just [`Self::bits`]. [`Self::bits`] tracks
    /// the first entry (the precision score/serve default to).
    pub build_bits: Vec<u8>,
    /// Quantization scheme for 2–8 bits: absmax | absmean.
    pub scheme: Scheme,
    /// Streaming-builder memory budget in MiB: bounds the fp32 row window
    /// plus every target precision's packed window, so peak build memory
    /// is independent of the corpus size.
    pub build_mem_budget_mb: usize,
    /// Quantize-stage worker cap for the streaming builder (0 = the
    /// persistent pool's full width). Output bytes are identical at every
    /// worker count.
    pub build_workers: usize,
    /// Rows `qless ingest` appends to the run's existing datastores as
    /// one new generation (0 = ingest is a no-op; the ingest command
    /// requires it > 0).
    pub ingest_rows: usize,
    /// Base-model weight quantization (QLoRA ablation): 16 | 8 | 4.
    pub model_bits: u8,
    /// Validation few-shot samples per benchmark used for selection.
    pub val_per_task: usize,
    /// Eval set size per benchmark.
    pub eval_per_task: usize,
    /// Extraction/scoring worker threads.
    pub workers: usize,
    /// Use the XLA (AOT kernel) scoring path instead of the native one.
    pub xla_score: bool,
    /// Rows per influence-scan shard; 0 = derive from `mem_budget_mb`.
    pub shard_rows: usize,
    /// Influence-scan memory budget in MiB (bounds the streamed shard
    /// buffers; the scan never materializes a whole checkpoint block).
    pub mem_budget_mb: usize,
    /// Score all benchmarks' validation tasks in ONE streamed datastore
    /// pass (per-task accumulators share the shard traversal). Disable to
    /// fall back to one pass per benchmark (before/after comparisons).
    pub multi_scan: bool,
    /// `qless serve` bind address, `host:port` (port 0 = ephemeral).
    pub serve_addr: String,
    /// Serve: micro-batch admission window in milliseconds — how long the
    /// scoring worker waits after the first pending query to coalesce
    /// concurrent queries into one fused datastore pass.
    pub batch_window_ms: u64,
    /// Serve: most validation tasks fused into one scan pass (≥ 1).
    pub max_batch_tasks: usize,
    /// Serve: score-cache capacity in entries (one per distinct task
    /// digest); 0 disables score caching.
    pub score_cache_entries: usize,
    /// Serve: datastore file to serve; empty = the pipeline's default
    /// path under `run_dir` for the configured bits/scheme.
    pub datastore: String,
    /// Serve: spawn N in-process scan workers behind a scatter-gather
    /// coordinator (0 = single-node resident serving). Each worker serves
    /// the same datastore; the coordinator partitions the row space.
    pub local_workers: usize,
    /// Serve: comma-separated `host:port` list of already-running remote
    /// scan workers to coordinate (empty = none). Mutually exclusive with
    /// `local_workers`.
    pub worker_addrs: String,
    /// Serve: per-worker request deadline in milliseconds; a worker that
    /// misses it is treated as failed and its row range re-issued.
    pub worker_deadline_ms: u64,
    /// Serve: how many times a failed/timed-out row range is re-issued to
    /// the remaining healthy workers before the query degrades to an
    /// error response.
    pub worker_retries: usize,
    /// Two-stage precision cascade, `PROBE,RERANK` bit pair (e.g. `1,8`):
    /// stage 1 scans every row at the cheap probe precision and keeps the
    /// top `cascade_mult × k` candidates per task; stage 2 re-scores only
    /// those rows at the rerank precision. Empty = exhaustive scan at
    /// [`Self::bits`]. Both precisions must exist in the run directory
    /// (build with `--bits PROBE,RERANK`).
    pub cascade: String,
    /// Cascade candidate multiplier `c`: stage 1 keeps `c·k` candidates
    /// per task for stage 2 (k = final selections). Larger c = higher
    /// recall, more rerank I/O; `c·k ≥ n` makes the cascade exact.
    pub cascade_mult: usize,
    /// Clusters for `qless reindex`'s IVF sidecar build (0 = auto:
    /// `⌈√n⌉`, clamped to 4096). The sidecar lives next to each store as
    /// `<stem>.qidx` and arms the sub-linear `--nprobe` read path.
    pub nclusters: usize,
    /// Clusters probed per task by `qless score --nprobe P` (0 = don't
    /// use the index — exhaustive scan). `P ≥` the sidecar's cluster
    /// count is byte-identical to exhaustive; smaller trades recall for
    /// rows read. Requires a sidecar built by `qless reindex`.
    pub nprobe: usize,
    /// `qless stats` refresh interval in seconds (0 = scrape once and
    /// exit). Each refresh is one `metrics` + one `stats` round trip.
    pub watch: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            model: "small".into(),
            artifacts: "artifacts".into(),
            run_dir: "runs/default".into(),
            corpus_size: 8000,
            seed: 17,
            warmup_frac: 0.05,
            warmup_epochs: 4,
            select_frac: 0.05,
            finetune_epochs: 4,
            lr: 1e-3,
            lr_warmup_frac: 0.03,
            bits: 16,
            build_bits: Vec::new(),
            scheme: Scheme::Absmax,
            build_mem_budget_mb: DEFAULT_MEM_BUDGET_MB,
            build_workers: 0,
            ingest_rows: 0,
            model_bits: 16,
            val_per_task: 32,
            eval_per_task: 128,
            workers: default_workers(),
            xla_score: false,
            shard_rows: 0,
            mem_budget_mb: DEFAULT_MEM_BUDGET_MB,
            multi_scan: true,
            serve_addr: "127.0.0.1:7411".into(),
            batch_window_ms: 2,
            max_batch_tasks: 16,
            score_cache_entries: 64,
            datastore: String::new(),
            local_workers: 0,
            worker_addrs: String::new(),
            worker_deadline_ms: 2000,
            worker_retries: 2,
            cascade: String::new(),
            cascade_mult: qless_datastore::influence::DEFAULT_CASCADE_MULT,
            nclusters: 0,
            nprobe: 0,
            watch: 0,
        }
    }
}

pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get().min(8)).unwrap_or(4)
}

impl Config {
    /// Every key [`Config::set`] accepts (underscore form; dashes are
    /// interchangeable on the CLI). The docs-sync test greps these against
    /// the usage texts so a new knob cannot ship undocumented.
    pub const KEYS: &'static [&'static str] = &[
        "model",
        "artifacts",
        "run_dir",
        "corpus_size",
        "seed",
        "warmup_frac",
        "warmup_epochs",
        "select_frac",
        "finetune_epochs",
        "lr",
        "lr_warmup_frac",
        "bits",
        "build_mem_budget_mb",
        "build_workers",
        "ingest_rows",
        "scheme",
        "model_bits",
        "val_per_task",
        "eval_per_task",
        "workers",
        "xla_score",
        "shard_rows",
        "mem_budget_mb",
        "multi_scan",
        "serve_addr",
        "batch_window_ms",
        "max_batch_tasks",
        "score_cache_entries",
        "datastore",
        "local_workers",
        "worker_addrs",
        "worker_deadline_ms",
        "worker_retries",
        "cascade",
        "cascade_mult",
        "nclusters",
        "nprobe",
        "watch",
    ];

    /// Apply one `key = value` (file) or `--key value` (CLI) assignment.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let key = key.replace('-', "_");
        let v = value.trim();
        match key.as_str() {
            "model" => self.model = v.to_string(),
            "artifacts" => self.artifacts = v.to_string(),
            "run_dir" => self.run_dir = v.to_string(),
            "corpus_size" => self.corpus_size = parse(v, &key)?,
            "seed" => self.seed = parse(v, &key)?,
            "warmup_frac" => self.warmup_frac = parse_frac(v, &key)?,
            "warmup_epochs" => self.warmup_epochs = parse(v, &key)?,
            "select_frac" => self.select_frac = parse_frac(v, &key)?,
            "finetune_epochs" => self.finetune_epochs = parse(v, &key)?,
            "lr" => self.lr = parse(v, &key)?,
            "lr_warmup_frac" => self.lr_warmup_frac = parse_frac(v, &key)?,
            "bits" => {
                // a single value or a comma list — a list arms the
                // streaming builder's one-pass multi-precision sweep
                let mut list: Vec<u8> = Vec::new();
                for part in v.split(',') {
                    let part = part.trim();
                    if part.is_empty() {
                        bail!("empty entry in bits list '{v}'");
                    }
                    let b: u8 = parse(part, &key)?;
                    if ![1, 2, 4, 8, 16].contains(&b) {
                        bail!("bits must be one of 1,2,4,8,16 (got {b})");
                    }
                    if list.contains(&b) {
                        bail!("duplicate bits {b} in list '{v}'");
                    }
                    list.push(b);
                }
                self.bits = list[0];
                self.build_bits = if list.len() == 1 { Vec::new() } else { list };
            }
            "build_mem_budget_mb" => self.build_mem_budget_mb = parse(v, &key)?,
            "build_workers" => self.build_workers = parse(v, &key)?,
            "ingest_rows" => self.ingest_rows = parse(v, &key)?,
            "scheme" => self.scheme = v.parse()?,
            "model_bits" => {
                self.model_bits = parse(v, &key)?;
                if ![4, 8, 16].contains(&self.model_bits) {
                    bail!("model_bits must be one of 4,8,16 (got {})", self.model_bits);
                }
            }
            "val_per_task" => self.val_per_task = parse(v, &key)?,
            "eval_per_task" => self.eval_per_task = parse(v, &key)?,
            "workers" => self.workers = parse(v, &key)?,
            "xla_score" => self.xla_score = parse_bool(v, &key)?,
            "shard_rows" => self.shard_rows = parse(v, &key)?,
            "mem_budget_mb" => self.mem_budget_mb = parse(v, &key)?,
            "multi_scan" => self.multi_scan = parse_bool(v, &key)?,
            "serve_addr" => self.serve_addr = v.to_string(),
            "batch_window_ms" => self.batch_window_ms = parse(v, &key)?,
            "max_batch_tasks" => self.max_batch_tasks = parse(v, &key)?,
            "score_cache_entries" => self.score_cache_entries = parse(v, &key)?,
            "datastore" => self.datastore = v.to_string(),
            "local_workers" => self.local_workers = parse(v, &key)?,
            "worker_addrs" => self.worker_addrs = v.to_string(),
            "worker_deadline_ms" => self.worker_deadline_ms = parse(v, &key)?,
            "worker_retries" => self.worker_retries = parse(v, &key)?,
            "cascade" => self.cascade = v.to_string(),
            "cascade_mult" => self.cascade_mult = parse(v, &key)?,
            "nclusters" => self.nclusters = parse(v, &key)?,
            "nprobe" => self.nprobe = parse(v, &key)?,
            "watch" => self.watch = parse(v, &key)?,
            _ => bail!("unknown config key '{key}'"),
        }
        Ok(())
    }

    /// Parse a `key = value` config file (comments with `#`, blank lines ok).
    pub fn load_file(&mut self, path: &Path) -> Result<()> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("{path:?}:{}: expected key = value", lineno + 1))?;
            self.set(k.trim(), v.trim())
                .with_context(|| format!("{path:?}:{}", lineno + 1))?;
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        if !(0.0..=1.0).contains(&self.warmup_frac) {
            bail!("warmup_frac out of [0,1]");
        }
        if !(0.0..=1.0).contains(&self.select_frac) {
            bail!("select_frac out of [0,1]");
        }
        if self.corpus_size < 100 {
            bail!("corpus_size too small (< 100)");
        }
        if self.workers == 0 {
            bail!("workers must be >= 1");
        }
        for &b in self.effective_bits() {
            if b != 16 && b != 1 && self.scheme == Scheme::Sign {
                bail!("scheme=sign only valid at 1-bit");
            }
        }
        if self.mem_budget_mb == 0 {
            bail!("mem_budget_mb must be >= 1 (use shard_rows for explicit shard sizing)");
        }
        if self.build_mem_budget_mb == 0 {
            bail!("build_mem_budget_mb must be >= 1");
        }
        if self.max_batch_tasks == 0 {
            bail!("max_batch_tasks must be >= 1 (1 disables fusing, not serving)");
        }
        if self.batch_window_ms > 60_000 {
            bail!("batch_window_ms {} is over a minute — surely a typo", self.batch_window_ms);
        }
        if self.serve_addr.is_empty() {
            bail!("serve_addr must be host:port (port 0 for ephemeral)");
        }
        if self.local_workers > 0 && !self.worker_addrs.is_empty() {
            bail!("local_workers and worker_addrs are mutually exclusive");
        }
        if self.local_workers > 64 {
            bail!("local_workers {} — over 64 in one process is surely a typo", self.local_workers);
        }
        if self.worker_deadline_ms == 0 || self.worker_deadline_ms > 600_000 {
            bail!(
                "worker_deadline_ms must be in [1, 600000], got {}",
                self.worker_deadline_ms
            );
        }
        if !self.worker_addrs.is_empty() {
            for a in self.worker_addrs.split(',') {
                let a = a.trim();
                if a.is_empty() || !a.contains(':') {
                    bail!("worker_addrs entry '{a}' is not host:port");
                }
            }
        }
        self.cascade_precisions()?; // parse errors surface at validate time
        if self.cascade_mult == 0 {
            bail!("cascade_mult must be >= 1");
        }
        if self.nclusters > 1 << 20 {
            bail!("nclusters {} — over 2^20 clusters is surely a typo", self.nclusters);
        }
        if self.nprobe > 1 << 20 {
            bail!("nprobe {} — over 2^20 probed clusters is surely a typo", self.nprobe);
        }
        Ok(())
    }

    /// The list form of [`Self::worker_addrs`] (trimmed, empty = none).
    pub fn worker_addr_list(&self) -> Vec<String> {
        if self.worker_addrs.is_empty() {
            Vec::new()
        } else {
            self.worker_addrs.split(',').map(|a| a.trim().to_string()).collect()
        }
    }

    /// Map the serve-facing config fields onto the serving crate's
    /// [`qless_service::service::ServeOpts`] (the layered workspace keeps
    /// `qless-service` below this crate, so the mapping lives here).
    pub fn serve_opts(&self) -> qless_service::service::ServeOpts {
        qless_service::service::ServeOpts {
            addr: self.serve_addr.clone(),
            batch_window_ms: self.batch_window_ms,
            max_batch_tasks: self.max_batch_tasks,
            shard_rows: self.shard_rows,
            mem_budget_mb: self.mem_budget_mb,
            score_cache_entries: self.score_cache_entries,
            workers: self.workers,
            queue_cap: 256,
        }
    }

    /// Map the coordinator-facing config fields onto the serving crate's
    /// [`qless_service::service::CoordinatorOpts`].
    pub fn coordinator_opts(&self) -> qless_service::service::CoordinatorOpts {
        qless_service::service::CoordinatorOpts {
            addr: self.serve_addr.clone(),
            workers: self.worker_addr_list(),
            queue_cap: 256,
            deadline: std::time::Duration::from_millis(self.worker_deadline_ms),
            retries: self.worker_retries,
        }
    }

    /// The bitwidths a datastore build targets: the `--bits` list when one
    /// was given, else just [`Self::bits`].
    fn effective_bits(&self) -> &[u8] {
        if self.build_bits.is_empty() {
            std::slice::from_ref(&self.bits)
        } else {
            &self.build_bits
        }
    }

    /// The precisions a one-pass datastore build targets, in `--bits`
    /// order. The configured scheme applies to the 2/4/8-bit entries;
    /// 1-bit coerces to sign and 16-bit to absmax ([`crate::quant::Precision::new`]).
    pub fn precisions(&self) -> Result<Vec<crate::quant::Precision>> {
        self.effective_bits()
            .iter()
            .map(|&b| crate::quant::Precision::new(b, self.scheme))
            .collect()
    }

    /// The `--cascade PROBE,RERANK` pair as precisions, `None` when the
    /// knob is unset (exhaustive scan). The configured scheme applies to
    /// 2/4/8-bit entries; 1-bit coerces to sign and 16-bit to absmax,
    /// exactly like [`Self::precisions`].
    pub fn cascade_precisions(
        &self,
    ) -> Result<Option<(crate::quant::Precision, crate::quant::Precision)>> {
        if self.cascade.is_empty() {
            return Ok(None);
        }
        let parts: Vec<&str> = self.cascade.split(',').map(str::trim).collect();
        if parts.len() != 2 || parts.iter().any(|p| p.is_empty()) {
            bail!("cascade must be 'PROBE,RERANK' bits (e.g. '1,8'), got '{}'", self.cascade);
        }
        let mut bits = [0u8; 2];
        for (slot, part) in bits.iter_mut().zip(&parts) {
            let b: u8 = parse(part, "cascade")?;
            if ![1, 2, 4, 8, 16].contains(&b) {
                bail!("cascade bits must be one of 1,2,4,8,16 (got {b})");
            }
            *slot = b;
        }
        if bits[0] == bits[1] {
            bail!("cascade probe and rerank bits must differ (got {},{})", bits[0], bits[1]);
        }
        if bits[0] > bits[1] {
            bail!(
                "cascade probe bits must be below rerank bits ({},{} re-scores at a \
                 cheaper precision than the probe — swap them)",
                bits[0],
                bits[1]
            );
        }
        let probe = crate::quant::Precision::new(bits[0], self.scheme)?;
        let rerank = crate::quant::Precision::new(bits[1], self.scheme)?;
        Ok(Some((probe, rerank)))
    }

    /// The method label used in report tables (paper naming).
    pub fn method_label(&self) -> String {
        match self.bits {
            16 => "LESS 16-bit".to_string(),
            1 => "QLESS 1-bit".to_string(),
            b => format!("QLESS {b}-bit ({})", self.scheme),
        }
    }
}

fn parse<T: std::str::FromStr>(v: &str, key: &str) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    v.parse::<T>().map_err(|e| anyhow::anyhow!("bad value '{v}' for {key}: {e}"))
}

fn parse_frac(v: &str, key: &str) -> Result<f64> {
    let f: f64 = parse(v, key)?;
    if !(0.0..=1.0).contains(&f) {
        bail!("{key} must be in [0,1], got {f}");
    }
    Ok(f)
}

fn parse_bool(v: &str, key: &str) -> Result<bool> {
    match v {
        "true" | "1" | "yes" => Ok(true),
        "false" | "0" | "no" => Ok(false),
        _ => bail!("bad bool '{v}' for {key}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn set_and_dashes() {
        let mut c = Config::default();
        c.set("corpus-size", "4000").unwrap();
        assert_eq!(c.corpus_size, 4000);
        c.set("bits", "1").unwrap();
        assert_eq!(c.bits, 1);
        c.set("scheme", "absmean").unwrap();
    }

    #[test]
    fn rejects_bad_values() {
        let mut c = Config::default();
        assert!(c.set("bits", "3").is_err());
        assert!(c.set("model_bits", "2").is_err());
        assert!(c.set("warmup_frac", "1.5").is_err());
        assert!(c.set("nonsense", "1").is_err());
        assert!(c.set("xla_score", "maybe").is_err());
        assert!(c.set("shard_rows", "lots").is_err());
        assert!(c.set("mem_budget_mb", "-3").is_err());
    }

    #[test]
    fn bits_list_arms_the_one_pass_sweep() {
        let mut c = Config::default();
        assert!(c.build_bits.is_empty());
        assert_eq!(c.precisions().unwrap().len(), 1); // follows `bits`
        c.set("bits", "1,2,4,8,16").unwrap();
        assert_eq!(c.bits, 1, "first list entry becomes the primary precision");
        assert_eq!(c.build_bits, vec![1, 2, 4, 8, 16]);
        let ps = c.precisions().unwrap();
        assert_eq!(ps.len(), 5);
        assert_eq!(ps[0].scheme, Scheme::Sign); // 1-bit coerces
        assert_eq!(ps[4].scheme, Scheme::Absmax); // 16-bit coerces
        c.validate().unwrap();
        // whitespace tolerated, singles reset the list
        c.set("bits", " 8 , 4 ").unwrap();
        assert_eq!(c.build_bits, vec![8, 4]);
        c.set("bits", "4").unwrap();
        assert!(c.build_bits.is_empty());
        assert_eq!(c.bits, 4);
        // bad lists rejected
        assert!(c.set("bits", "4,4").is_err());
        assert!(c.set("bits", "4,3").is_err());
        assert!(c.set("bits", "4,,8").is_err());
    }

    #[test]
    fn keys_const_is_exhaustive_and_accepted() {
        // every listed key must reach a real setter (no "unknown config
        // key"), and every key the setter knows must be listed — a new
        // knob that skips KEYS also skips the docs-sync usage check
        for key in Config::KEYS {
            let mut c = Config::default();
            if let Err(e) = c.set(key, "1") {
                let msg = format!("{e:#}");
                assert!(
                    !msg.contains("unknown config key"),
                    "KEYS lists '{key}' but set() does not know it"
                );
            }
        }
        let mut c = Config::default();
        let err = c.set("definitely_not_a_key", "1").unwrap_err();
        assert!(format!("{err:#}").contains("unknown config key"));
    }

    #[test]
    fn ingest_rows_parses() {
        let mut c = Config::default();
        assert_eq!(c.ingest_rows, 0);
        c.set("ingest-rows", "250").unwrap();
        assert_eq!(c.ingest_rows, 250);
        c.validate().unwrap();
        assert!(c.set("ingest_rows", "lots").is_err());
    }

    #[test]
    fn build_knobs_parse_and_validate() {
        let mut c = Config::default();
        assert_eq!(c.build_mem_budget_mb, DEFAULT_MEM_BUDGET_MB);
        assert_eq!(c.build_workers, 0); // auto
        c.set("build-mem-budget-mb", "16").unwrap();
        c.set("build-workers", "3").unwrap();
        assert_eq!(c.build_mem_budget_mb, 16);
        assert_eq!(c.build_workers, 3);
        c.validate().unwrap();
        c.set("build_mem_budget_mb", "0").unwrap();
        assert!(c.validate().is_err());
    }

    #[test]
    fn sign_scheme_rejected_anywhere_in_bits_list() {
        let mut c = Config::default();
        c.set("bits", "1,16").unwrap();
        c.scheme = Scheme::Sign;
        c.validate().unwrap(); // 1 and 16 both fine under sign
        c.set("bits", "1,4").unwrap();
        assert!(c.validate().is_err(), "4-bit sign must be rejected");
    }

    #[test]
    fn multi_scan_flag_parses() {
        let mut c = Config::default();
        assert!(c.multi_scan); // one datastore pass for all benchmarks
        c.set("multi-scan", "false").unwrap();
        assert!(!c.multi_scan);
        c.set("multi_scan", "yes").unwrap();
        assert!(c.multi_scan);
        assert!(c.set("multi_scan", "perhaps").is_err());
    }

    #[test]
    fn scan_knobs_parse_and_validate() {
        let mut c = Config::default();
        assert_eq!(c.shard_rows, 0); // auto (budget-derived)
        assert_eq!(c.mem_budget_mb, 64);
        c.set("shard-rows", "4096").unwrap();
        c.set("mem-budget-mb", "128").unwrap();
        assert_eq!(c.shard_rows, 4096);
        assert_eq!(c.mem_budget_mb, 128);
        c.validate().unwrap();
        c.set("mem_budget_mb", "0").unwrap();
        assert!(c.validate().is_err());
    }

    #[test]
    fn serve_knobs_parse_and_validate() {
        let mut c = Config::default();
        assert_eq!(c.serve_addr, "127.0.0.1:7411");
        assert_eq!(c.batch_window_ms, 2);
        assert_eq!(c.max_batch_tasks, 16);
        assert_eq!(c.score_cache_entries, 64);
        assert!(c.datastore.is_empty());
        c.set("serve-addr", "0.0.0.0:9000").unwrap();
        c.set("batch-window-ms", "7").unwrap();
        c.set("max-batch-tasks", "32").unwrap();
        c.set("score-cache-entries", "0").unwrap(); // 0 = disabled, valid
        c.set("datastore", "runs/x/ds.qlds").unwrap();
        assert_eq!(c.serve_addr, "0.0.0.0:9000");
        assert_eq!(c.batch_window_ms, 7);
        assert_eq!(c.max_batch_tasks, 32);
        assert_eq!(c.score_cache_entries, 0);
        c.validate().unwrap();
        c.set("max_batch_tasks", "0").unwrap();
        assert!(c.validate().is_err());
        c.set("max_batch_tasks", "4").unwrap();
        c.set("batch_window_ms", "61000").unwrap();
        assert!(c.validate().is_err());
        c.set("batch_window_ms", "2").unwrap();
        c.serve_addr.clear();
        assert!(c.validate().is_err());
        assert!(c.set("batch_window_ms", "fast").is_err());
    }

    #[test]
    fn load_file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("qless_cfg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("a.cfg");
        std::fs::write(&p, "# comment\ncorpus_size = 2000\nbits = 4 # inline\n\nscheme=absmean\n").unwrap();
        let mut c = Config::default();
        c.load_file(&p).unwrap();
        assert_eq!(c.corpus_size, 2000);
        assert_eq!(c.bits, 4);
        assert_eq!(c.scheme, Scheme::Absmean);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_file_reports_line() {
        let dir = std::env::temp_dir().join(format!("qless_cfg2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("b.cfg");
        std::fs::write(&p, "corpus_size\n").unwrap();
        let err = Config::default().load_file(&p).unwrap_err().to_string();
        assert!(err.contains(":1"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn method_labels() {
        let mut c = Config::default();
        assert_eq!(c.method_label(), "LESS 16-bit");
        c.bits = 1;
        assert_eq!(c.method_label(), "QLESS 1-bit");
        c.bits = 4;
        assert!(c.method_label().starts_with("QLESS 4-bit"));
    }

    #[test]
    fn distributed_serve_knobs_parse_and_validate() {
        let mut c = Config::default();
        assert_eq!(c.local_workers, 0); // single-node resident serving
        assert!(c.worker_addrs.is_empty());
        assert_eq!(c.worker_deadline_ms, 2000);
        assert_eq!(c.worker_retries, 2);
        assert!(c.worker_addr_list().is_empty());
        c.set("local-workers", "3").unwrap();
        c.set("worker-deadline-ms", "500").unwrap();
        c.set("worker-retries", "1").unwrap();
        assert_eq!((c.local_workers, c.worker_deadline_ms, c.worker_retries), (3, 500, 1));
        c.validate().unwrap();
        // local_workers and worker_addrs are mutually exclusive
        c.set("worker-addrs", "10.0.0.1:7411, 10.0.0.2:7411").unwrap();
        assert!(c.validate().is_err());
        c.set("local_workers", "0").unwrap();
        c.validate().unwrap();
        assert_eq!(c.worker_addr_list(), vec!["10.0.0.1:7411", "10.0.0.2:7411"]);
        // malformed address entries rejected
        c.set("worker_addrs", "nocolon").unwrap();
        assert!(c.validate().is_err());
        c.set("worker_addrs", "").unwrap();
        // deadline bounds
        c.set("worker_deadline_ms", "0").unwrap();
        assert!(c.validate().is_err());
        c.set("worker_deadline_ms", "700000").unwrap();
        assert!(c.validate().is_err());
        c.set("worker_deadline_ms", "2000").unwrap();
        c.set("local_workers", "65").unwrap();
        assert!(c.validate().is_err());
        assert!(c.set("worker_retries", "many").is_err());
    }

    #[test]
    fn serve_and_coordinator_opts_map_the_config() {
        let mut c = Config::default();
        c.set("serve-addr", "127.0.0.1:0").unwrap();
        c.set("batch-window-ms", "5").unwrap();
        c.set("shard-rows", "33").unwrap();
        c.set("worker-deadline-ms", "750").unwrap();
        c.set("worker-retries", "4").unwrap();
        let so = c.serve_opts();
        assert_eq!(so.addr, "127.0.0.1:0");
        assert_eq!(so.batch_window_ms, 5);
        assert_eq!(so.shard_rows, 33);
        assert_eq!(so.max_batch_tasks, c.max_batch_tasks);
        assert_eq!(so.mem_budget_mb, c.mem_budget_mb);
        assert_eq!(so.score_cache_entries, c.score_cache_entries);
        assert_eq!(so.workers, c.workers);
        let co = c.coordinator_opts();
        assert_eq!(co.addr, "127.0.0.1:0");
        assert_eq!(co.deadline, std::time::Duration::from_millis(750));
        assert_eq!(co.retries, 4);
        assert!(co.workers.is_empty());
        c.set("worker-addrs", "10.0.0.1:7411,10.0.0.2:7411").unwrap();
        assert_eq!(c.coordinator_opts().workers, vec!["10.0.0.1:7411", "10.0.0.2:7411"]);
    }

    #[test]
    fn cascade_knobs_parse_and_validate() {
        let mut c = Config::default();
        assert!(c.cascade.is_empty());
        assert_eq!(c.cascade_mult, 8);
        assert!(c.cascade_precisions().unwrap().is_none());
        c.set("cascade", "1,8").unwrap();
        c.set("cascade-mult", "4").unwrap();
        assert_eq!(c.cascade_mult, 4);
        let (probe, rerank) = c.cascade_precisions().unwrap().unwrap();
        assert_eq!((probe.bits, rerank.bits), (1, 8));
        assert_eq!(probe.scheme, Scheme::Sign); // 1-bit coerces
        assert_eq!(rerank.scheme, Scheme::Absmax);
        c.validate().unwrap();
        // whitespace tolerated
        c.set("cascade", " 2 , 16 ").unwrap();
        let (p2, r2) = c.cascade_precisions().unwrap().unwrap();
        assert_eq!((p2.bits, r2.bits), (2, 16));
        // malformed pairs are clean errors, never a silent exhaustive scan
        for bad in ["1", "1,8,16", "1,", "3,8", "8,8", "8,1", "one,8"] {
            c.set("cascade", bad).unwrap();
            assert!(c.validate().is_err(), "cascade '{bad}' must be rejected");
        }
        c.set("cascade", "1,8").unwrap();
        c.set("cascade_mult", "0").unwrap();
        assert!(c.validate().is_err(), "cascade_mult 0 must be rejected");
        assert!(c.set("cascade_mult", "lots").is_err());
    }

    #[test]
    fn index_knobs_parse_and_validate() {
        let mut c = Config::default();
        assert_eq!(c.nclusters, 0, "auto cluster count by default");
        assert_eq!(c.nprobe, 0, "exhaustive scan by default");
        c.set("nclusters", "64").unwrap();
        c.set("nprobe", "6").unwrap();
        assert_eq!((c.nclusters, c.nprobe), (64, 6));
        c.validate().unwrap();
        c.set("nclusters", "2097152").unwrap();
        assert!(c.validate().is_err(), "absurd nclusters must be rejected");
        c.set("nclusters", "0").unwrap();
        c.set("nprobe", "2097152").unwrap();
        assert!(c.validate().is_err(), "absurd nprobe must be rejected");
        assert!(c.set("nprobe", "some").is_err());
        assert!(c.set("nclusters", "-4").is_err());
    }

    #[test]
    fn watch_knob_parses() {
        let mut c = Config::default();
        assert_eq!(c.watch, 0, "scrape-once by default");
        c.set("watch", "5").unwrap();
        assert_eq!(c.watch, 5);
        c.validate().unwrap();
        assert!(c.set("watch", "forever").is_err());
    }

    #[test]
    fn sign_scheme_only_one_bit() {
        let mut c = Config::default();
        c.scheme = Scheme::Sign;
        c.bits = 4;
        assert!(c.validate().is_err());
        c.bits = 1;
        c.validate().unwrap();
    }
}
