//! # QLESS — Quantized Low-rank Gradient Similarity Search
//!
//! Rust reproduction of *"QLESS: A Quantized Approach for Data Valuation and
//! Selection in Large Language Model Fine-Tuning"* (cs.LG 2025).
//!
//! Three-layer architecture (see `DESIGN.md`):
//!
//! * **L3 (this crate)** — the data-valuation pipeline coordinator: corpus
//!   generation, warmup training, sharded gradient-feature extraction,
//!   quantized gradient datastore, influence scoring, top-p% selection,
//!   fine-tuning and benchmark evaluation. Python never runs here.
//! * **L2 (python/compile)** — SimLM (causal transformer + LoRA) fwd/bwd in
//!   JAX, AOT-lowered once to HLO text artifacts.
//! * **L1 (python/compile/kernels)** — Pallas kernels for quantization and
//!   the cosine-similarity influence matmul, lowered inside the L2 graphs.
//!
//! The [`runtime`] module loads `artifacts/*.hlo.txt` through the PJRT C API
//! (`xla` crate) and executes them from the hot path.

pub mod baselines;
pub mod config;
pub mod corpus;
pub mod data;
pub mod datastore;
pub mod eval;
pub mod experiments;
pub mod grads;
pub mod influence;
pub mod model;
pub mod pipeline;
pub mod quant;
pub mod runtime;
pub mod select;
pub mod train;
pub mod util;

pub use anyhow::{anyhow, bail, Context, Result};
