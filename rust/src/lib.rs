//! # QLESS — Quantized Low-rank Gradient Similarity Search
//!
//! Rust reproduction of *"QLESS: A Quantized Approach for Data Valuation and
//! Selection in Large Language Model Fine-Tuning"* (cs.LG 2025).
//!
//! Since the workspace split this is the **top crate** of a four-crate
//! cargo workspace (see `ARCHITECTURE.md` for the crate map and
//! `DESIGN.md` for the numbered design notes), with dependency edges only
//! pointing downward:
//!
//! * **`qless` (this crate)** — the data-valuation pipeline coordinator:
//!   corpus plumbing, warmup training, sharded gradient-feature
//!   extraction, top-p% selection analyses, fine-tuning and benchmark
//!   evaluation, experiments, and the CLI. Python never runs here.
//! * **`qless-service`** — the resident query service (`qless serve`):
//!   warm sessions, micro-batching, the JSON-lines protocol, the TCP
//!   server, and the distributed scatter-gather coordinator.
//! * **`qless-datastore`** — the QLDS on-disk format, the live
//!   append-only store + generation manifests, and the fused multi-query
//!   influence scans.
//! * **`qless-core`** — quantization, deterministic top-k selection, the
//!   PJRT runtime executing the AOT-lowered HLO artifacts, the synthetic
//!   corpus, and the zero-dependency util substrate.
//!
//! The lower crates' module trees are re-exported here under their
//! pre-split names (`qless::datastore`, `qless::influence`,
//! `qless::service`, `qless::quant`, …), so downstream code, the tests,
//! the benches and the examples address one crate.
//!
//! Below the Rust workspace sit **L2 (python/compile)** — SimLM (causal
//! transformer + LoRA) fwd/bwd in JAX, AOT-lowered once to HLO text
//! artifacts — and **L1 (python/compile/kernels)** — Pallas kernels for
//! quantization and the cosine-similarity influence matmul, lowered
//! inside the L2 graphs. The [`runtime`] module loads `artifacts/*.hlo.txt`
//! through the PJRT C API (`xla` crate) and executes them from the hot
//! path.
#![warn(missing_docs)]
#![deny(unsafe_op_in_unsafe_fn)]

// Modules below carry `allow(missing_docs)` until their rustdoc pass lands;
// the re-exported data-path crates (datastore → quant → influence →
// select → service) are fully documented and each crate's own
// `#![warn(missing_docs)]` keeps them that way.
#[allow(missing_docs)]
pub mod baselines;
#[allow(missing_docs)]
pub mod config;
#[allow(missing_docs)]
pub mod data;
#[allow(missing_docs)]
pub mod eval;
#[allow(missing_docs)]
pub mod experiments;
#[allow(missing_docs)]
pub mod grads;
#[allow(missing_docs)]
pub mod model;
#[allow(missing_docs)]
pub mod pipeline;
pub mod select;
#[allow(missing_docs)]
pub mod train;

pub use qless_core::{corpus, quant, runtime};
pub use qless_core::{debug, info, prop_assert, warn_};
pub use qless_datastore::{datastore, fixtures, influence, util};
pub use qless_service::service;

pub use anyhow::{anyhow, bail, Context, Result};
