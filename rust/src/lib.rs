//! # QLESS — Quantized Low-rank Gradient Similarity Search
//!
//! Rust reproduction of *"QLESS: A Quantized Approach for Data Valuation and
//! Selection in Large Language Model Fine-Tuning"* (cs.LG 2025).
//!
//! Three-layer architecture (see `ARCHITECTURE.md` for the module map and
//! `DESIGN.md` for the numbered design notes):
//!
//! * **L3 (this crate)** — the data-valuation pipeline coordinator: corpus
//!   generation, warmup training, sharded gradient-feature extraction,
//!   quantized gradient datastore, multi-query influence scoring on the
//!   integer-domain kernels, top-p% selection, fine-tuning and benchmark
//!   evaluation — plus the resident query service (`qless serve`) that
//!   keeps a datastore warm and answers influence queries over TCP
//!   ([`service`]). Python never runs here.
//! * **L2 (python/compile)** — SimLM (causal transformer + LoRA) fwd/bwd in
//!   JAX, AOT-lowered once to HLO text artifacts.
//! * **L1 (python/compile/kernels)** — Pallas kernels for quantization and
//!   the cosine-similarity influence matmul, lowered inside the L2 graphs.
//!
//! The [`runtime`] module loads `artifacts/*.hlo.txt` through the PJRT C API
//! (`xla` crate) and executes them from the hot path.
#![warn(missing_docs)]

// Modules below carry `allow(missing_docs)` until their rustdoc pass lands;
// the data-path modules (datastore → quant → influence → select) are fully
// documented and the crate-level warn keeps them that way.
#[allow(missing_docs)]
pub mod baselines;
#[allow(missing_docs)]
pub mod config;
#[allow(missing_docs)]
pub mod corpus;
#[allow(missing_docs)]
pub mod data;
pub mod datastore;
#[allow(missing_docs)]
pub mod eval;
#[allow(missing_docs)]
pub mod experiments;
#[allow(missing_docs)]
pub mod grads;
pub mod influence;
#[allow(missing_docs)]
pub mod model;
#[allow(missing_docs)]
pub mod pipeline;
pub mod quant;
#[allow(missing_docs)]
pub mod runtime;
pub mod select;
pub mod service;
#[allow(missing_docs)]
pub mod train;
#[allow(missing_docs)]
pub mod util;

pub use anyhow::{anyhow, bail, Context, Result};
