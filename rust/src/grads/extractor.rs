//! Sharded gradient-feature extraction over the worker pool.
//!
//! For one checkpoint: upload the checkpoint-lifetime operands (base, lora,
//! m, v, R) once as device buffers, then fan batches out to `workers`
//! threads that each call the `grad_train` graph; features stream back in
//! order through a [`Reorderer`] to a caller-supplied **row sink**
//! ([`extract_train_features_stream`]) — the streaming multi-precision
//! datastore builder's input side — or into a dense `[n × k]` matrix
//! ([`extract_train_features`], the explicit small-run opt-in that
//! materializes `n × k × 4` bytes).

use std::sync::Arc;

use anyhow::Result;

use crate::data::stream::{pipeline, Reorderer};
use crate::data::{Batch, Batcher, Dataset};
use crate::grads::Projector;
use crate::model::Checkpoint;
use crate::runtime::{ModelInfo, Runtime};
use crate::{debug, info};

pub use qless_core::grads::FeatureMatrix;

/// Extract Adam-preconditioned projected gradients Γ(z;θ)·R for every
/// sample of `data` at checkpoint `ckpt` (paper §2.2 / Eq. 1) into a dense
/// resident matrix.
///
/// This is the **small-run opt-in**: it materializes `n × k × 4` bytes.
/// The datastore build path must NOT go through this — it streams rows via
/// [`extract_train_features_stream`] so peak memory stays independent of
/// the corpus size.
pub fn extract_train_features(
    rt: &Runtime,
    info: &ModelInfo,
    base: &[f32],
    ckpt: &Checkpoint,
    data: &Dataset,
    proj: &Projector,
    workers: usize,
) -> Result<FeatureMatrix> {
    extract_features_dense(rt, info, base, ckpt, data, proj, workers, true)
}

/// Extract plain SGD projected gradients ∇ℓ(z';θ)·R (validation side).
/// Dense is fine here: validation sets are tiny (`val_per_task` rows).
pub fn extract_val_features(
    rt: &Runtime,
    info: &ModelInfo,
    base: &[f32],
    ckpt: &Checkpoint,
    data: &Dataset,
    proj: &Projector,
    workers: usize,
) -> Result<FeatureMatrix> {
    extract_features_dense(rt, info, base, ckpt, data, proj, workers, false)
}

/// Stream Adam-preconditioned train features **in sample order** to
/// `sink(start_row, rows)`, where `rows` is a contiguous chunk of
/// `rows.len() / k` feature rows beginning at global row `start_row`.
/// Chunks tile `0..n` exactly once, ascending. Only the in-flight batches
/// are ever resident — this is the streaming datastore builder's input.
/// A sink error aborts the extraction and is returned to the caller.
#[allow(clippy::too_many_arguments)]
pub fn extract_train_features_stream<F>(
    rt: &Runtime,
    info: &ModelInfo,
    base: &[f32],
    ckpt: &Checkpoint,
    data: &Dataset,
    proj: &Projector,
    workers: usize,
    sink: F,
) -> Result<()>
where
    F: FnMut(usize, &[f32]) -> Result<()> + Send,
{
    extract_train_features_stream_from(rt, info, base, ckpt, data, proj, workers, 0, sink)
}

/// [`extract_train_features_stream`] with a **resumable row offset**: only
/// rows `first_row..` of `data` are extracted (chunks tile that range
/// ascending, exactly once), and every chunk's start row is reported in
/// `data`'s own (global) row numbering — the library-level resume hook
/// for partial extraction (re-deriving the tail of a dataset without
/// re-extracting its stored prefix). `first_row = 0` is exactly the full
/// stream — zero-copy, no subset clone — and is how
/// [`extract_train_features_stream`] routes here; `first_row =
/// data.len()` extracts nothing.
#[allow(clippy::too_many_arguments)]
pub fn extract_train_features_stream_from<F>(
    rt: &Runtime,
    info: &ModelInfo,
    base: &[f32],
    ckpt: &Checkpoint,
    data: &Dataset,
    proj: &Projector,
    workers: usize,
    first_row: usize,
    mut sink: F,
) -> Result<()>
where
    F: FnMut(usize, &[f32]) -> Result<()> + Send,
{
    anyhow::ensure!(
        first_row <= data.len(),
        "row offset {first_row} past the corpus end ({} rows)",
        data.len()
    );
    if first_row == data.len() {
        return Ok(());
    }
    let k = info.proj_dim;
    // subset-clone only the tail actually being extracted — the full
    // stream (first_row = 0) must stay zero-copy, or every build would
    // hold a second corpus resident and break the bounded-memory contract
    let tail_storage;
    let tail: &Dataset = if first_row == 0 {
        data
    } else {
        let indices: Vec<usize> = (first_row..data.len()).collect();
        tail_storage = data.subset(&indices);
        &tail_storage
    };
    extract_features_sink(rt, info, base, ckpt, tail, proj, workers, true, |indices, rows| {
        // Batcher::sequential yields contiguous ascending indices; the
        // stream contract (ascending tiling chunks) depends on it.
        debug_assert!(indices.windows(2).all(|w| w[1] == w[0] + 1));
        debug_assert_eq!(rows.len(), indices.len() * k);
        sink(first_row + indices[0], rows)
    })
}

#[allow(clippy::too_many_arguments)]
fn extract_features_dense(
    rt: &Runtime,
    info: &ModelInfo,
    base: &[f32],
    ckpt: &Checkpoint,
    data: &Dataset,
    proj: &Projector,
    workers: usize,
    adam: bool,
) -> Result<FeatureMatrix> {
    let (n, k) = (data.len(), info.proj_dim);
    let mut out = vec![0f32; n * k];
    extract_features_sink(rt, info, base, ckpt, data, proj, workers, adam, |indices, rows| {
        for (r, &idx) in indices.iter().enumerate() {
            out[idx * k..(idx + 1) * k].copy_from_slice(&rows[r * k..(r + 1) * k]);
        }
        Ok(())
    })?;
    Ok(FeatureMatrix { n, k, data: out })
}

/// The shared extraction engine: producer → workers → in-order consumer,
/// handing each batch's real rows (indices + features) to `sink` in
/// sequence order. On a sink error the remaining in-flight results are
/// drained (not processed) so the worker pool shuts down cleanly, then the
/// error is returned.
#[allow(clippy::too_many_arguments)]
fn extract_features_sink<F>(
    rt: &Runtime,
    info: &ModelInfo,
    base: &[f32],
    ckpt: &Checkpoint,
    data: &Dataset,
    proj: &Projector,
    workers: usize,
    adam: bool,
    mut sink: F,
) -> Result<()>
where
    F: FnMut(&[usize], &[f32]) -> Result<()> + Send,
{
    assert_eq!(proj.d, info.d_lora);
    assert_eq!(proj.k, info.proj_dim);
    let (b, s, k) = (info.batch_grad, info.seq, info.proj_dim);
    let artifact = if adam { "grad_train" } else { "grad_val" };
    let exec = rt.exec(info, artifact)?;

    // checkpoint-lifetime operands: uploaded once, shared by all workers
    let base_buf = Arc::new(rt.upload_f32(base, &[info.d_base])?);
    let lora_buf = Arc::new(rt.upload_f32(&ckpt.lora, &[info.d_lora])?);
    let proj_buf = Arc::new(rt.upload_f32(&proj.matrix, &[proj.d, proj.k])?);
    let (m_buf, v_buf, t_buf) = if adam {
        // t=0 checkpoints (never trained) still need t ≥ 1 for bias correction.
        let t = ckpt.step.max(1) as f32;
        (
            Some(Arc::new(rt.upload_f32(&ckpt.m, &[info.d_lora])?)),
            Some(Arc::new(rt.upload_f32(&ckpt.v, &[info.d_lora])?)),
            Some(Arc::new(rt.upload_f32(&[t], &[])?)),
        )
    } else {
        (None, None, None)
    };

    let n = data.len();
    let t0 = std::time::Instant::now();

    // SAFETY-free concurrency: batches are produced on the caller thread,
    // executed by `workers` threads, and handed to the sink in order.
    pipeline(
        workers,
        workers * 2,
        |tx| {
            for (i, batch) in Batcher::sequential(data, b).enumerate() {
                if tx.send((i, batch)).is_err() {
                    return; // consumer aborted (sink or worker error)
                }
            }
        },
        |_seq, batch: Batch| -> Result<(Vec<usize>, Vec<f32>)> {
            let tok_buf = rt.upload_i32(&batch.tokens, &[b, s])?;
            let mask_buf = rt.upload_f32(&batch.masks, &[b, s])?;
            let outs = if adam {
                exec.run_b(&[
                    &base_buf,
                    &lora_buf,
                    m_buf.as_deref().unwrap(),
                    v_buf.as_deref().unwrap(),
                    t_buf.as_deref().unwrap(),
                    &tok_buf,
                    &mask_buf,
                    &proj_buf,
                ])?
            } else {
                exec.run_b(&[&base_buf, &lora_buf, &tok_buf, &mask_buf, &proj_buf])?
            };
            Ok((batch.indices, outs.into_iter().next().expect("one output")))
        },
        |rx| -> Result<()> {
            let mut reorder = Reorderer::new();
            let mut done = 0usize;
            let mut fail: Option<anyhow::Error> = None;
            for (seq, res) in rx {
                if fail.is_some() {
                    continue; // drain remaining in-flight results
                }
                match res {
                    Ok((indices, feats)) => {
                        let mut sink_err = None;
                        reorder.push(seq, (indices, feats), |_, (indices, feats)| {
                            if sink_err.is_some() {
                                return;
                            }
                            let take = indices.len() * k;
                            match sink(&indices, &feats[..take]) {
                                Ok(()) => done += indices.len(),
                                Err(e) => sink_err = Some(e),
                            }
                        });
                        fail = sink_err;
                    }
                    Err(e) => fail = Some(e),
                }
            }
            debug!("extraction consumer wrote {done} rows");
            match fail {
                Some(e) => Err(e),
                None => Ok(()),
            }
        },
    )?;

    info!(
        "{artifact}: {n} samples × k={k} in {:.2}s ({:.0} samples/s, {workers} workers)",
        t0.elapsed().as_secs_f64(),
        n as f64 / t0.elapsed().as_secs_f64().max(1e-9)
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{generate_corpus, Tokenizer};
    use std::path::PathBuf;

    fn rt() -> Option<Runtime> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("manifest.json").exists().then(|| Runtime::new(&p).unwrap())
    }

    fn setup(rt: &Runtime) -> (ModelInfo, Vec<f32>, Checkpoint, Dataset, Projector) {
        let info = rt.model("tiny").unwrap();
        let tok = Tokenizer::default();
        let data = Dataset::encode(generate_corpus(40, 3, &tok, info.seq), &tok, info.seq);
        let base = crate::model::init_base(&info, 1);
        let ckpt = Checkpoint::fresh(info.d_lora, crate::model::init_lora(&info, 1));
        let proj = Projector::new(3, info.d_lora, info.proj_dim);
        (info, base, ckpt, data, proj)
    }

    #[test]
    fn features_are_deterministic_and_nonzero() {
        let Some(rt) = rt() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let (info, base, ckpt, data, proj) = setup(&rt);
        let a = extract_val_features(&rt, &info, &base, &ckpt, &data, &proj, 2).unwrap();
        let b = extract_val_features(&rt, &info, &base, &ckpt, &data, &proj, 4).unwrap();
        assert_eq!(a.n, 40);
        assert_eq!(a.k, info.proj_dim);
        // worker count must not change results
        for i in 0..a.data.len() {
            assert!((a.data[i] - b.data[i]).abs() < 1e-5, "idx {i}");
        }
        // every row must be non-trivial (all samples have loss-masked tokens)
        for i in 0..a.n {
            let norm: f32 = a.row(i).iter().map(|x| x * x).sum();
            assert!(norm > 0.0, "zero gradient row {i}");
        }
    }

    #[test]
    fn stream_matches_dense_and_tiles_in_order() {
        let Some(rt) = rt() else {
            return;
        };
        let (info, base, ckpt, data, proj) = setup(&rt);
        let dense = extract_train_features(&rt, &info, &base, &ckpt, &data, &proj, 3).unwrap();
        let k = info.proj_dim;
        let mut streamed = vec![f32::NAN; data.len() * k];
        let mut next = 0usize;
        extract_train_features_stream(&rt, &info, &base, &ckpt, &data, &proj, 3, |start, rows| {
            assert_eq!(start, next, "chunks must tile ascending");
            streamed[start * k..start * k + rows.len()].copy_from_slice(rows);
            next = start + rows.len() / k;
            Ok(())
        })
        .unwrap();
        assert_eq!(next, data.len());
        for i in 0..dense.data.len() {
            assert!((dense.data[i] - streamed[i]).abs() < 1e-6, "idx {i}");
        }

        // a sink error must abort the stream and surface the error
        let err = extract_train_features_stream(
            &rt,
            &info,
            &base,
            &ckpt,
            &data,
            &proj,
            2,
            |_start, _rows| anyhow::bail!("sink says no"),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("sink says no"));
    }

    #[test]
    fn stream_from_skips_the_prefix_and_keeps_global_rows() {
        // The resumable-offset stream must tile exactly [first_row, n),
        // report starts in the full dataset's row numbering, and match the
        // dense extraction row-for-row (the ingest path's contract).
        let Some(rt) = rt() else {
            return;
        };
        let (info, base, ckpt, data, proj) = setup(&rt);
        let dense = extract_train_features(&rt, &info, &base, &ckpt, &data, &proj, 2).unwrap();
        let k = info.proj_dim;
        let first = 17usize;
        let mut next = first;
        extract_train_features_stream_from(
            &rt,
            &info,
            &base,
            &ckpt,
            &data,
            &proj,
            2,
            first,
            |start, rows| {
                assert_eq!(start, next, "chunks must tile ascending from first_row");
                for (j, row) in rows.chunks(k).enumerate() {
                    let g = start + j;
                    for (a, b) in dense.row(g).iter().zip(row) {
                        assert!((a - b).abs() < 1e-5, "row {g}");
                    }
                }
                next = start + rows.len() / k;
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(next, data.len());
        // offset at the end extracts nothing; past the end is an error
        extract_train_features_stream_from(
            &rt,
            &info,
            &base,
            &ckpt,
            &data,
            &proj,
            2,
            data.len(),
            |_, _| panic!("no rows expected"),
        )
        .unwrap();
        assert!(extract_train_features_stream_from(
            &rt,
            &info,
            &base,
            &ckpt,
            &data,
            &proj,
            2,
            data.len() + 1,
            |_, _| Ok(()),
        )
        .is_err());
    }

    #[test]
    fn train_and_val_features_differ() {
        // Adam preconditioning must change the features (even at m=v=0 the
        // normalization by sqrt(v̂)+eps rescales per-coordinate).
        let Some(rt) = rt() else {
            return;
        };
        let (info, base, ckpt, data, proj) = setup(&rt);
        let small = data.subset(&(0..8).collect::<Vec<_>>());
        let tr = extract_train_features(&rt, &info, &base, &ckpt, &small, &proj, 2).unwrap();
        let va = extract_val_features(&rt, &info, &base, &ckpt, &small, &proj, 2).unwrap();
        let diff: f32 = tr.data.iter().zip(&va.data).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1.0, "adam preconditioning had no effect: {diff}");
    }
}
