//! The shared random-projection matrix R (paper Eq. 1 / §2.4 QRP).
//!
//! R ∈ {−1,+1}^{d_lora×k}/√k is derived from the run seed via the
//! Python-parity splitmix64 stream (`util::rng`), generated once per run
//! and uploaded once per checkpoint as a persistent device buffer — it is
//! by far the largest per-call operand of the `grad_*` graphs
//! (d_lora × k × 4 bytes), so keeping it resident matters (§Perf).

use crate::util::rng::rademacher_projection;

#[derive(Debug, Clone)]
pub struct Projector {
    pub seed: u64,
    pub d: usize,
    pub k: usize,
    pub matrix: Vec<f32>,
}

impl Projector {
    /// Derive the projection for a run. The seed is folded with a fixed tag
    /// so corpus/selection RNG and the projection never share a stream.
    pub fn new(run_seed: u64, d: usize, k: usize) -> Projector {
        let seed = run_seed ^ 0x5EED_0F_0E57;
        Projector { seed, d, k, matrix: rademacher_projection(seed, d, k) }
    }

    /// Host-side projection of one gradient row (tests / native paths).
    pub fn project(&self, g: &[f32]) -> Vec<f32> {
        assert_eq!(g.len(), self.d);
        let mut out = vec![0f32; self.k];
        for (i, &gi) in g.iter().enumerate() {
            if gi == 0.0 {
                continue;
            }
            let row = &self.matrix[i * self.k..(i + 1) * self.k];
            for (o, r) in out.iter_mut().zip(row) {
                *o += gi * r;
            }
        }
        out
    }

    pub fn bytes(&self) -> usize {
        self.matrix.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_run_seed() {
        let a = Projector::new(7, 32, 16);
        let b = Projector::new(7, 32, 16);
        assert_eq!(a.matrix, b.matrix);
        assert_ne!(a.matrix, Projector::new(8, 32, 16).matrix);
    }

    #[test]
    fn values_are_scaled_signs() {
        let p = Projector::new(1, 8, 4);
        let s = 1.0 / 2.0;
        assert!(p.matrix.iter().all(|&v| v == s || v == -s));
        assert_eq!(p.bytes(), 8 * 4 * 4);
    }

    #[test]
    fn project_matches_naive_matmul() {
        let p = Projector::new(3, 16, 8);
        let mut rng = crate::util::Rng::new(5);
        let g: Vec<f32> = (0..16).map(|_| rng.normal() as f32).collect();
        let fast = p.project(&g);
        let mut slow = vec![0f32; 8];
        for (j, s) in slow.iter_mut().enumerate() {
            *s = (0..16).map(|i| g[i] * p.matrix[i * 8 + j]).sum();
        }
        for (a, b) in fast.iter().zip(&slow) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn projection_preserves_norm_in_expectation() {
        let p = Projector::new(9, 256, 128);
        let mut rng = crate::util::Rng::new(6);
        let g: Vec<f32> = (0..256).map(|_| rng.normal() as f32).collect();
        let y = p.project(&g);
        let ng: f32 = g.iter().map(|x| x * x).sum();
        let ny: f32 = y.iter().map(|x| x * x).sum();
        assert!((ny / ng - 1.0).abs() < 0.35, "JL norm ratio {}", ny / ng);
    }
}
