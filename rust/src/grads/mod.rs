//! Gradient-feature extraction — LESS/QLESS step 2.
//!
//! For every training sample × checkpoint: per-sample Adam-preconditioned
//! LoRA gradient, projected to `k` dims by the shared Rademacher matrix
//! (the `grad_train` AOT graph). Validation gradients use plain SGD grads
//! (`grad_val`). Extraction is sharded over a worker-thread pool, each
//! worker driving PJRT executions with checkpoint-lifetime operands held in
//! persistent device buffers.

pub mod extractor;
pub mod projector;

pub use extractor::{
    extract_train_features, extract_train_features_stream, extract_train_features_stream_from,
    extract_val_features,
};
pub use projector::Projector;
pub use qless_core::grads::FeatureMatrix;
