//! SimLM parameter handling on the Rust side.
//!
//! The L2 graphs treat parameters as flat f32 vectors; this module owns
//! their initialization (bit-matching `model.init_*_flat` is not required —
//! init happens on whichever side creates the checkpoint, and all tests of
//! numerical parity run through the AOT graphs), the shape bookkeeping
//! mirrored from the manifest, and binary checkpoint (de)serialization.

pub mod checkpoint;

pub use checkpoint::{Checkpoint, CheckpointSet};

use crate::runtime::ModelInfo;
use crate::util::Rng;

/// Initialize the frozen base parameters (scaled-normal matrices, unit
/// RMSNorm gains) following the same scheme as `model.init_base_flat`.
pub fn init_base(info: &ModelInfo, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed).fork(0xBA5E);
    let mut out = Vec::with_capacity(info.d_base);
    let (v, d, f) = (info.vocab, info.d_model, info.d_ff);
    // embed [V, D]
    push_normal(&mut out, v * d, 0.05, &mut rng);
    for _ in 0..info.n_layers {
        for _ in 0..4 {
            // wq wk wv wo [D, D], 1/sqrt(fan_in)
            push_normal(&mut out, d * d, 1.0 / (d as f32).sqrt(), &mut rng);
        }
        push_ones(&mut out, d); // ln1
        push_normal(&mut out, d * f, 1.0 / (d as f32).sqrt(), &mut rng); // w1
        push_normal(&mut out, f * d, 1.0 / (f as f32).sqrt(), &mut rng); // w2
        push_ones(&mut out, d); // ln2
    }
    push_ones(&mut out, d); // lnf
    assert_eq!(out.len(), info.d_base, "base param count mismatch");
    out
}

/// Initialize LoRA params: A ~ N(0, 1/r), B = 0 (adapters start as no-op).
pub fn init_lora(info: &ModelInfo, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed).fork(0x10BA);
    let (d, r) = (info.d_model, info.lora_rank);
    let mut out = Vec::with_capacity(info.d_lora);
    for _ in 0..info.n_layers {
        for _ in 0..4 {
            push_normal(&mut out, d * r, 1.0 / (r as f32).sqrt(), &mut rng); // A
            push_zeros(&mut out, r * d); // B
        }
    }
    assert_eq!(out.len(), info.d_lora, "lora param count mismatch");
    out
}

fn push_normal(out: &mut Vec<f32>, n: usize, scale: f32, rng: &mut Rng) {
    out.extend((0..n).map(|_| rng.normal() as f32 * scale));
}

fn push_ones(out: &mut Vec<f32>, n: usize) {
    out.extend(std::iter::repeat_n(1.0f32, n));
}

fn push_zeros(out: &mut Vec<f32>, n: usize) {
    out.extend(std::iter::repeat_n(0.0f32, n));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;
    use std::path::PathBuf;

    fn tiny() -> Option<ModelInfo> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("manifest.json")
            .exists()
            .then(|| Manifest::load(&p).unwrap().model("tiny").unwrap().clone())
    }

    #[test]
    fn init_sizes_match_manifest() {
        let Some(info) = tiny() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert_eq!(init_base(&info, 1).len(), info.d_base);
        assert_eq!(init_lora(&info, 1).len(), info.d_lora);
    }

    #[test]
    fn init_deterministic_and_seed_sensitive() {
        let Some(info) = tiny() else {
            return;
        };
        assert_eq!(init_base(&info, 1), init_base(&info, 1));
        assert_ne!(init_base(&info, 1), init_base(&info, 2));
    }

    #[test]
    fn lora_b_blocks_are_zero() {
        let Some(info) = tiny() else {
            return;
        };
        let lora = init_lora(&info, 3);
        let (d, r) = (info.d_model, info.lora_rank);
        let mut off = 0;
        for _ in 0..info.n_layers * 4 {
            let a = &lora[off..off + d * r];
            assert!(a.iter().any(|&x| x != 0.0));
            off += d * r;
            let b = &lora[off..off + r * d];
            assert!(b.iter().all(|&x| x == 0.0));
            off += r * d;
        }
    }

    #[test]
    fn base_norm_gains_are_ones() {
        let Some(info) = tiny() else {
            return;
        };
        let base = init_base(&info, 4);
        // lnf is the last d_model entries
        let lnf = &base[info.d_base - info.d_model..];
        assert!(lnf.iter().all(|&x| x == 1.0));
    }
}
