//! Binary checkpoint format: LoRA params + Adam state + the LR weight η_i
//! each warmup epoch contributes to influence aggregation (paper Eq. 7).
//!
//! Layout: magic "QLCK" | version u32 | d_lora u64 | step u64 | eta f32 |
//! lora | m | v (f32 little-endian). The frozen base is stored once per run
//! as a bare f32 dump (`base.bin`) since it never changes.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

const MAGIC: [u8; 4] = *b"QLCK";
const VERSION: u32 = 1;

#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// Optimizer step count at save time (1-based, drives bias correction).
    pub step: u64,
    /// Learning rate at this checkpoint — the η_i of paper Eq. 7.
    pub eta: f32,
    pub lora: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
}

impl Checkpoint {
    pub fn fresh(d_lora: usize, lora: Vec<f32>) -> Checkpoint {
        assert_eq!(lora.len(), d_lora);
        Checkpoint { step: 0, eta: 0.0, lora, m: vec![0.0; d_lora], v: vec![0.0; d_lora] }
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(p) = path.parent() {
            std::fs::create_dir_all(p)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(&MAGIC)?;
        f.write_all(&VERSION.to_le_bytes())?;
        f.write_all(&(self.lora.len() as u64).to_le_bytes())?;
        f.write_all(&self.step.to_le_bytes())?;
        f.write_all(&self.eta.to_le_bytes())?;
        for part in [&self.lora, &self.m, &self.v] {
            write_f32s(&mut f, part)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening checkpoint {path:?}"))?,
        );
        let mut hdr = [0u8; 4 + 4 + 8 + 8 + 4];
        f.read_exact(&mut hdr)?;
        if hdr[0..4] != MAGIC {
            bail!("bad checkpoint magic");
        }
        let version = u32::from_le_bytes(hdr[4..8].try_into()?);
        if version != VERSION {
            bail!("checkpoint version {version} != {VERSION}");
        }
        let d = u64::from_le_bytes(hdr[8..16].try_into()?) as usize;
        let step = u64::from_le_bytes(hdr[16..24].try_into()?);
        let eta = f32::from_le_bytes(hdr[24..28].try_into()?);
        let lora = read_f32s(&mut f, d)?;
        let m = read_f32s(&mut f, d)?;
        let v = read_f32s(&mut f, d)?;
        Ok(Checkpoint { step, eta, lora, m, v })
    }
}

/// The warmup run's outputs: base params + the N epoch checkpoints.
#[derive(Debug, Clone)]
pub struct CheckpointSet {
    pub base: Vec<f32>,
    pub checkpoints: Vec<Checkpoint>,
}

impl CheckpointSet {
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let mut f = std::io::BufWriter::new(std::fs::File::create(dir.join("base.bin"))?);
        write_f32s(&mut f, &self.base)?;
        for (i, c) in self.checkpoints.iter().enumerate() {
            c.save(&Self::ckpt_path(dir, i))?;
        }
        Ok(())
    }

    pub fn load(dir: &Path, d_base: usize) -> Result<CheckpointSet> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(dir.join("base.bin"))
                .with_context(|| format!("opening {dir:?}/base.bin — run warmup first"))?,
        );
        let base = read_f32s(&mut f, d_base)?;
        let mut checkpoints = Vec::new();
        for i in 0.. {
            let p = Self::ckpt_path(dir, i);
            if !p.exists() {
                break;
            }
            checkpoints.push(Checkpoint::load(&p)?);
        }
        if checkpoints.is_empty() {
            bail!("no checkpoints in {dir:?}");
        }
        Ok(CheckpointSet { base, checkpoints })
    }

    pub fn ckpt_path(dir: &Path, i: usize) -> PathBuf {
        dir.join(format!("ckpt_{i:02}.qlck"))
    }
}

fn write_f32s<W: Write>(w: &mut W, xs: &[f32]) -> Result<()> {
    // bulk little-endian write
    let mut buf = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&buf)?;
    Ok(())
}

fn read_f32s<R: Read>(r: &mut R, n: usize) -> Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf).context("checkpoint truncated")?;
    Ok(buf.chunks(4).map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]])).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir() -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "qless_ck_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn checkpoint_roundtrip() {
        let dir = tmpdir();
        let c = Checkpoint {
            step: 42,
            eta: 1.5e-3,
            lora: vec![1.0, -2.0, 3.5],
            m: vec![0.1, 0.2, 0.3],
            v: vec![0.01, 0.02, 0.03],
        };
        let p = dir.join("c.qlck");
        c.save(&p).unwrap();
        assert_eq!(Checkpoint::load(&p).unwrap(), c);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn set_roundtrip_and_ordering() {
        let dir = tmpdir();
        let set = CheckpointSet {
            base: vec![9.0; 7],
            checkpoints: (0..3)
                .map(|i| Checkpoint {
                    step: i as u64 + 1,
                    eta: i as f32,
                    lora: vec![i as f32; 4],
                    m: vec![0.0; 4],
                    v: vec![0.0; 4],
                })
                .collect(),
        };
        set.save(&dir).unwrap();
        let back = CheckpointSet::load(&dir, 7).unwrap();
        assert_eq!(back.base, set.base);
        assert_eq!(back.checkpoints.len(), 3);
        for (a, b) in back.checkpoints.iter().zip(&set.checkpoints) {
            assert_eq!(a, b);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fresh_has_zero_state() {
        let c = Checkpoint::fresh(4, vec![1.0; 4]);
        assert_eq!(c.m, vec![0.0; 4]);
        assert_eq!(c.step, 0);
    }

    #[test]
    fn load_missing_is_informative() {
        let err = CheckpointSet::load(Path::new("/nonexistent"), 4).unwrap_err();
        assert!(format!("{err:#}").contains("warmup"));
    }

    #[test]
    fn corrupt_magic_rejected() {
        let dir = tmpdir();
        let p = dir.join("bad.qlck");
        std::fs::write(&p, b"NOPE............................").unwrap();
        assert!(Checkpoint::load(&p).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
