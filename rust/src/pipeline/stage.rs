//! Explicit pipeline stages + the stage runner.
//!
//! The pipeline used to be a web of ad-hoc methods with inline timing
//! prints. [`PipelineStageRunner`] names every stage ([`Stage`]), times
//! each run, counts cache hits, and renders a per-stage cost table that
//! reports and benches can emit — the cost model behind one Table-1 row.

use crate::info;
use crate::util::table::Table;

/// The pipeline's stages, in execution order (Fig. 2 of the paper).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Pretrain the shared base model (cached per model/seed).
    Pretrain,
    /// LoRA warmup on the 5% subset → N checkpoints.
    Warmup,
    /// Per-checkpoint gradient-feature extraction (train side).
    ExtractTrain,
    /// Per-checkpoint gradient-feature extraction (validation side).
    ExtractVal,
    /// Streaming datastore build: extract → quantize → write, all
    /// requested precisions in one fused pass (io units = peak builder
    /// bytes).
    BuildDatastore,
    /// Incremental ingest: extract → quantize → append new corpus rows as
    /// one segment per precision + a generation bump (io units = rows
    /// appended).
    Ingest,
    /// Streamed influence scan (Eq. 7) over datastore shards.
    Score,
    /// Top-p% selection.
    Select,
    /// LoRA fine-tune on the selected subset.
    Finetune,
    /// Benchmark evaluation.
    Evaluate,
}

impl Stage {
    pub const ALL: [Stage; 10] = [
        Stage::Pretrain,
        Stage::Warmup,
        Stage::ExtractTrain,
        Stage::ExtractVal,
        Stage::BuildDatastore,
        Stage::Ingest,
        Stage::Score,
        Stage::Select,
        Stage::Finetune,
        Stage::Evaluate,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Stage::Pretrain => "pretrain",
            Stage::Warmup => "warmup",
            Stage::ExtractTrain => "extract-train",
            Stage::ExtractVal => "extract-val",
            Stage::BuildDatastore => "build-datastore",
            Stage::Ingest => "ingest",
            Stage::Score => "score",
            Stage::Select => "select",
            Stage::Finetune => "finetune",
            Stage::Evaluate => "evaluate",
        }
    }
}

impl std::fmt::Display for Stage {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Accumulated cost of one stage across a pipeline's lifetime.
#[derive(Debug, Clone, Copy, Default)]
pub struct StageCost {
    /// Executions recorded against the stage.
    pub runs: u32,
    /// Executions served from cache instead of run.
    pub cache_hits: u32,
    /// Total wall-clock seconds across all runs.
    pub secs: f64,
    /// Stage-defined I/O units. For [`Stage::Score`]: datastore shard
    /// reads — the multi-query scan's proof that Q validation tasks cost
    /// one pass, not Q. For [`Stage::BuildDatastore`]: peak builder bytes
    /// — the streaming build's proof that memory is window-bounded, not
    /// `O(n)`.
    pub io_units: u64,
}

/// Times stage executions and accumulates a per-stage cost table.
#[derive(Debug, Default)]
pub struct PipelineStageRunner {
    costs: [StageCost; Stage::ALL.len()],
}

impl PipelineStageRunner {
    pub fn new() -> PipelineStageRunner {
        PipelineStageRunner::default()
    }

    fn slot(&mut self, stage: Stage) -> &mut StageCost {
        let idx = Stage::ALL.iter().position(|s| *s == stage).expect("stage in ALL");
        &mut self.costs[idx]
    }

    /// Run one stage execution, recording wall-clock against it.
    pub fn run<T, E>(&mut self, stage: Stage, f: impl FnOnce() -> Result<T, E>) -> Result<T, E> {
        let t0 = std::time::Instant::now();
        let out = f();
        self.record(stage, t0.elapsed().as_secs_f64());
        out
    }

    /// Record one externally-timed execution of a stage. Methods that
    /// need `&mut self` for the work itself use this instead of [`run`]
    /// (a closure would borrow the runner and the pipeline at once).
    pub fn record(&mut self, stage: Stage, secs: f64) {
        let cost = self.slot(stage);
        cost.runs += 1;
        cost.secs += secs;
        info!("stage {stage}: {secs:.2}s (total {:.2}s over {} runs)", cost.secs, cost.runs);
    }

    /// Record that a stage was served from cache (no work done).
    pub fn cache_hit(&mut self, stage: Stage) {
        self.slot(stage).cache_hits += 1;
    }

    /// Add stage-defined I/O units to a stage (e.g. shard reads performed
    /// by an influence scan — see [`StageCost::io_units`]).
    pub fn add_units(&mut self, stage: Stage, units: u64) {
        self.slot(stage).io_units += units;
    }

    /// Raise a stage's I/O units to at least `units` — for stages whose
    /// units are a **high-water mark** rather than an additive counter
    /// ([`Stage::BuildDatastore`]'s peak builder bytes: two builds in one
    /// process must report the larger peak, not the sum).
    pub fn max_units(&mut self, stage: Stage, units: u64) {
        let cost = self.slot(stage);
        cost.io_units = cost.io_units.max(units);
    }

    pub fn cost(&self, stage: Stage) -> StageCost {
        let idx = Stage::ALL.iter().position(|s| *s == stage).expect("stage in ALL");
        self.costs[idx]
    }

    pub fn total_secs(&self) -> f64 {
        self.costs.iter().map(|c| c.secs).sum()
    }

    /// JSON mirror of the cost table (stable numbers for report
    /// artifacts; idle stages are skipped).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut j = Json::obj();
        for stage in Stage::ALL {
            let c = self.cost(stage);
            if c.runs == 0 && c.cache_hits == 0 {
                continue;
            }
            let mut s = Json::obj();
            s.set("runs", c.runs as usize);
            s.set("cache_hits", c.cache_hits as usize);
            s.set("secs", c.secs);
            s.set("io_units", c.io_units as usize);
            j.set(stage.name(), s);
        }
        j.set("total_secs", self.total_secs());
        j
    }

    /// Render the per-stage cost table (stages that never ran are skipped).
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "pipeline stage costs",
            &["stage", "runs", "cache hits", "secs", "io units"],
        );
        for stage in Stage::ALL {
            let c = self.cost(stage);
            if c.runs == 0 && c.cache_hits == 0 {
                continue;
            }
            t.row(vec![
                stage.name().to_string(),
                c.runs.to_string(),
                c.cache_hits.to_string(),
                format!("{:.2}", c.secs),
                c.io_units.to_string(),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_runs_and_cache_hits() {
        let mut r = PipelineStageRunner::new();
        let v: Result<i32, ()> = r.run(Stage::Score, || Ok(41 + 1));
        assert_eq!(v.unwrap(), 42);
        r.cache_hit(Stage::Score);
        r.cache_hit(Stage::Warmup);
        let c = r.cost(Stage::Score);
        assert_eq!(c.runs, 1);
        assert_eq!(c.cache_hits, 1);
        assert!(c.secs >= 0.0);
        assert_eq!(r.cost(Stage::Warmup).runs, 0);
        assert_eq!(r.cost(Stage::Pretrain).runs, 0);
    }

    #[test]
    fn io_units_accumulate() {
        let mut r = PipelineStageRunner::new();
        let _: Result<(), ()> = r.run(Stage::Score, || Ok(()));
        r.add_units(Stage::Score, 7);
        r.add_units(Stage::Score, 7);
        assert_eq!(r.cost(Stage::Score).io_units, 14);
        assert_eq!(r.cost(Stage::Select).io_units, 0);
    }

    #[test]
    fn max_units_is_a_high_water_mark() {
        let mut r = PipelineStageRunner::new();
        r.max_units(Stage::BuildDatastore, 100);
        r.max_units(Stage::BuildDatastore, 40); // later smaller build
        assert_eq!(r.cost(Stage::BuildDatastore).io_units, 100);
        r.max_units(Stage::BuildDatastore, 250);
        assert_eq!(r.cost(Stage::BuildDatastore).io_units, 250);
    }

    #[test]
    fn errors_propagate_and_still_count() {
        let mut r = PipelineStageRunner::new();
        let v: Result<(), String> = r.run(Stage::Finetune, || Err("boom".to_string()));
        assert!(v.is_err());
        assert_eq!(r.cost(Stage::Finetune).runs, 1);
    }

    #[test]
    fn table_skips_idle_stages() {
        let mut r = PipelineStageRunner::new();
        let _: Result<(), ()> = r.run(Stage::Evaluate, || Ok(()));
        let rendered = r.table().render();
        assert!(rendered.contains("evaluate"));
        assert!(!rendered.contains("pretrain"));
    }

    #[test]
    fn all_stages_named_uniquely() {
        let mut names: Vec<_> = Stage::ALL.iter().map(|s| s.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), Stage::ALL.len());
    }
}
