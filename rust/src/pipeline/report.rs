//! Experiment report emitter: every `xp` harness prints its paper-style
//! table to the console and writes `reports/<id>.md` + `reports/<id>.json`
//! so EXPERIMENTS.md can reference stable artifacts.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::pipeline::stage::PipelineStageRunner;
use crate::util::json::Json;
use crate::util::table::Table;

#[derive(Debug, Default)]
pub struct Report {
    pub id: String,
    pub title: String,
    pub tables: Vec<Table>,
    pub notes: Vec<String>,
    pub json: Json,
}

impl Report {
    pub fn new(id: &str, title: &str) -> Report {
        Report {
            id: id.to_string(),
            title: title.to_string(),
            tables: Vec::new(),
            notes: Vec::new(),
            json: Json::obj(),
        }
    }

    pub fn add_table(&mut self, t: Table) -> &mut Self {
        self.tables.push(t);
        self
    }

    pub fn note(&mut self, s: impl Into<String>) -> &mut Self {
        self.notes.push(s.into());
        self
    }

    /// Attach the pipeline's per-stage cost table (and mirror it into the
    /// JSON artifact so EXPERIMENTS.md can cite stable numbers).
    pub fn add_stage_costs(&mut self, stages: &PipelineStageRunner) -> &mut Self {
        self.json.set("stage_costs", stages.to_json());
        self.add_table(stages.table())
    }

    pub fn render(&self) -> String {
        let mut out = format!("# {} — {}\n\n", self.id, self.title);
        for t in &self.tables {
            out.push_str(&t.render());
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(&format!("> {n}\n"));
        }
        out
    }

    /// Print to stdout and persist under `reports/`.
    pub fn emit(&self, reports_dir: &Path) -> Result<PathBuf> {
        let text = self.render();
        println!("{text}");
        std::fs::create_dir_all(reports_dir)?;
        let md = reports_dir.join(format!("{}.md", self.id));
        std::fs::write(&md, &text)?;
        let json_path = reports_dir.join(format!("{}.json", self.id));
        std::fs::write(&json_path, self.json.encode_pretty())?;
        Ok(md)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_costs_render() {
        use crate::pipeline::stage::Stage;
        let mut stages = PipelineStageRunner::new();
        let _: Result<(), ()> = stages.run(Stage::Score, || Ok(()));
        stages.cache_hit(Stage::Warmup);
        let mut r = Report::new("stage_tbl", "stage cost smoke");
        r.add_stage_costs(&stages);
        let text = r.render();
        assert!(text.contains("score"));
        assert!(r.json.encode_pretty().contains("stage_costs"));
    }

    #[test]
    fn render_and_emit() {
        let mut r = Report::new("test_tbl", "smoke");
        let mut t = Table::new("rows", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        r.add_table(t);
        r.note("a note");
        r.json.set("x", 1usize);
        let dir = std::env::temp_dir().join(format!("qless_rep_{}", std::process::id()));
        let md = r.emit(&dir).unwrap();
        let text = std::fs::read_to_string(md).unwrap();
        assert!(text.contains("# test_tbl"));
        assert!(text.contains("| 1 | 2 |"));
        assert!(text.contains("> a note"));
        let j = std::fs::read_to_string(dir.join("test_tbl.json")).unwrap();
        assert!(Json::parse(&j).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }
}
