//! The end-to-end QLESS pipeline coordinator (Fig. 2 of the paper):
//!
//! ```text
//! pretrain base ─► warmup (LoRA, 5%, N epochs → N checkpoints)
//!    ─► per-checkpoint gradient features (train: Adam·R, val: SGD·R)
//!    ─► quantize → gradient datastore (per precision)
//!    ─► influence scores per benchmark ─► top-p% selection
//!    ─► LoRA fine-tune on the selection ─► benchmark eval
//! ```
//!
//! [`Pipeline`] owns the caches that make experiment grids affordable: the
//! pretrained base and warmup checkpoints are computed once per
//! (model, seed); raw fp32 features are extracted once and re-quantized
//! per precision; validation features are shared across precisions.

pub mod report;
pub mod runner;
pub mod stage;

pub use report::Report;
pub use runner::{IngestReport, Method, MethodResult, Pipeline};
pub use stage::{PipelineStageRunner, Stage, StageCost};
