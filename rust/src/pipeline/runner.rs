//! Pipeline runner: stage orchestration + caching. This is the L3 system
//! the experiment harnesses (`xp_*`) and examples drive.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::baselines;
use crate::config::Config;
use crate::corpus::{generate_corpus, Tokenizer, World};
use crate::data::Dataset;
use crate::datastore::{
    default_store_path, repair_run_dir, segment_store_path, Datastore, LiveStore, Manifest,
    MultiWriter, QuantIndex, SegmentWriter,
};
use crate::eval::benchmarks::{validation_samples, Benchmark};
use crate::eval::harness::{evaluate, BenchScores};
use crate::grads::{
    extract_train_features, extract_train_features_stream, extract_val_features, FeatureMatrix,
    Projector,
};
use crate::influence::{
    cascade, cascade_live_tasks, index_cascade_live_tasks, index_scan_live_tasks,
    score_datastore_tasks, score_live_tasks, CascadeOpts, IndexOpts, ScanStats, ScoreOpts,
};
use crate::model::{init_base, init_lora, Checkpoint, CheckpointSet};
use crate::pipeline::stage::{PipelineStageRunner, Stage};
use crate::quant::weights::quantize_weights;
use crate::quant::Precision;
use crate::runtime::{ModelInfo, Runtime};
use crate::select::{select_top_frac, SourceDistribution};
use crate::train::{Schedule, Trainer};
use crate::util::Rng;
use crate::{info, warn_};

/// A data-selection method from the paper's tables.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Method {
    Random100,
    RandomFrac,
    /// LESS (bits=16) and QLESS (bits<16) share the full pipeline.
    Qless(Precision),
}

impl Method {
    pub fn label(&self, cfg: &Config) -> String {
        match self {
            Method::Random100 => "random 100%".into(),
            Method::RandomFrac => format!("random {:.0}%", cfg.select_frac * 100.0),
            Method::Qless(p) if p.bits == 16 => "LESS 16-bit".into(),
            Method::Qless(p) => format!("QLESS {}", p.label()),
        }
    }
}

/// Buffer contiguous feature-row chunks into `window_floats`-float
/// windows, handing each **full** window to `append` (the caller flushes
/// the final partial window after its stream ends). The single windowing
/// loop shared by the streaming build and the ingest paths, so their
/// peak-memory behavior cannot diverge.
fn fill_windows(
    window: &mut Vec<f32>,
    window_floats: usize,
    mut rows: &[f32],
    mut append: impl FnMut(&[f32]) -> Result<()>,
) -> Result<()> {
    while !rows.is_empty() {
        let room = window_floats - window.len();
        let take = room.min(rows.len());
        window.extend_from_slice(&rows[..take]);
        rows = &rows[take..];
        if window.len() == window_floats {
            append(window)?;
            window.clear();
        }
    }
    Ok(())
}

/// Everything one `qless ingest` run appended (see
/// [`Pipeline::ingest_datastores`]).
#[derive(Debug, Clone)]
pub struct IngestReport {
    /// The generation the ingest published.
    pub generation: u64,
    /// Global row index of the first appended row.
    pub start_row: usize,
    /// Rows appended.
    pub rows: usize,
    /// Per-precision segment file sizes, in request order.
    pub segment_bytes: Vec<u64>,
}

/// Everything a method run produces (one row of Table 1).
#[derive(Debug, Clone)]
pub struct MethodResult {
    pub label: String,
    /// Benchmark → score (fraction).
    pub scores: BTreeMap<&'static str, f64>,
    pub average: f64,
    /// Measured datastore bytes (0 for random baselines).
    pub storage_bytes: u64,
    /// Benchmark → selected-subset source composition (Fig. 5).
    pub distributions: BTreeMap<&'static str, SourceDistribution>,
    /// Benchmark → selected indices.
    pub selections: BTreeMap<&'static str, Vec<usize>>,
    /// Fine-tune loss curves (benchmark → per-epoch mean losses).
    pub loss_curves: BTreeMap<&'static str, Vec<f64>>,
}

pub struct Pipeline {
    pub cfg: Config,
    pub rt: Runtime,
    pub info: ModelInfo,
    pub tok: Tokenizer,
    pub world: World,
    pub corpus: Dataset,
    /// Per-stage wall-clock + cache accounting (the run's cost model).
    pub stages: PipelineStageRunner,
    base: Option<Vec<f32>>,
    warmup: Option<CheckpointSet>,
    /// (benchmark → per-checkpoint validation features). Validation sets
    /// are tiny (`val_per_task` rows); train features are never retained —
    /// the datastore build streams them ([`Pipeline::build_datastores`]).
    val_features: BTreeMap<&'static str, Vec<FeatureMatrix>>,
}

impl Pipeline {
    pub fn new(cfg: Config) -> Result<Pipeline> {
        cfg.validate()?;
        let rt = Runtime::new(std::path::Path::new(&cfg.artifacts))?;
        let info = rt.model(&cfg.model)?;
        let tok = Tokenizer::default();
        let world = World::generate(cfg.seed);
        info!(
            "pipeline: model={} d_base={} d_lora={} k={} corpus={}",
            info.name, info.d_base, info.d_lora, info.proj_dim, cfg.corpus_size
        );
        let corpus = Dataset::encode(
            generate_corpus(cfg.corpus_size, cfg.seed, &tok, info.seq),
            &tok,
            info.seq,
        );
        Ok(Pipeline {
            cfg,
            rt,
            info,
            tok,
            world,
            corpus,
            stages: PipelineStageRunner::new(),
            base: None,
            warmup: None,
            val_features: BTreeMap::new(),
        })
    }

    /// The per-stage cost table accumulated so far (for reports/benches).
    pub fn stage_table(&self) -> crate::util::table::Table {
        self.stages.table()
    }

    pub fn run_dir(&self) -> PathBuf {
        PathBuf::from(&self.cfg.run_dir)
    }

    // ------------------------------------------------------------------
    // stage 0: pretrained base (the stand-in for the paper's LLM)
    // ------------------------------------------------------------------

    /// Pretrain the base on a *generic* corpus (disjoint seed from the
    /// selection corpus) so LoRA fine-tunes start from a model that knows
    /// the character-level "language". Cached on disk per (model, seed).
    pub fn base(&mut self) -> Result<Vec<f32>> {
        if let Some(b) = &self.base {
            return Ok(b.clone());
        }
        let path = self.run_dir().join("pretrain").join("base.bin");
        if path.exists() {
            let set = CheckpointSet::load(path.parent().unwrap(), self.info.d_base);
            if let Ok(set) = set {
                info!("loaded cached pretrained base");
                self.stages.cache_hit(Stage::Pretrain);
                self.base = Some(set.base.clone());
                return Ok(set.base);
            }
        }
        let t0 = std::time::Instant::now();
        let pre_corpus = Dataset::encode(
            generate_corpus(
                self.cfg.corpus_size.clamp(2048, 6144),
                self.cfg.seed ^ 0x11BE_7E57,
                &self.tok,
                self.info.seq,
            ),
            &self.tok,
            self.info.seq,
        );
        let mut base = init_base(&self.info, self.cfg.seed);
        // Pretraining stands in for the paper's pretrained LLM: long enough
        // that the base has the "language" + task formats (DESIGN.md §2);
        // it is cached on disk, so the cost is paid once per (model, seed).
        let epochs = 10usize;
        let steps = epochs * pre_corpus.len().div_ceil(self.info.batch_train);
        let sched = Schedule::new(3e-3, steps, 0.05);
        self.pretrain(&mut base, &pre_corpus, epochs, &sched)?;
        info!("pretrained base in {:.1}s ({} samples × {epochs} epochs)", t0.elapsed().as_secs_f64(), pre_corpus.len());
        // persist (reuse CheckpointSet layout with a dummy checkpoint)
        let set = CheckpointSet {
            base: base.clone(),
            checkpoints: vec![Checkpoint::fresh(self.info.d_lora, init_lora(&self.info, self.cfg.seed))],
        };
        set.save(&self.run_dir().join("pretrain"))?;
        self.stages.record(Stage::Pretrain, t0.elapsed().as_secs_f64());
        self.base = Some(base.clone());
        Ok(base)
    }

    fn pretrain(
        &self,
        base: &mut Vec<f32>,
        data: &Dataset,
        epochs: usize,
        sched: &Schedule,
    ) -> Result<()> {
        let exec = self.rt.exec(&self.info, "pretrain_step")?;
        let (b, s, db) = (self.info.batch_train, self.info.seq, self.info.d_base);
        let mut m = vec![0f32; db];
        let mut v = vec![0f32; db];
        let mut rng = Rng::new(self.cfg.seed).fork(0x11BE);
        let mut t = 0u64;
        for epoch in 0..epochs {
            let mut ep_loss = 0f64;
            let mut nb = 0;
            for batch in crate::data::Batcher::shuffled(data, b, &mut rng) {
                let lr = sched.lr(t as usize);
                t += 1;
                let out = exec.run(&[
                    crate::runtime::Arg::F32(base, &[db]),
                    crate::runtime::Arg::F32(&m, &[db]),
                    crate::runtime::Arg::F32(&v, &[db]),
                    crate::runtime::Arg::ScalarF32(t as f32),
                    crate::runtime::Arg::I32(&batch.tokens, &[b, s]),
                    crate::runtime::Arg::F32(&batch.masks, &[b, s]),
                    crate::runtime::Arg::ScalarF32(lr as f32),
                ])?;
                let [b2, m2, v2, loss]: [Vec<f32>; 4] =
                    out.try_into().map_err(|_| anyhow::anyhow!("pretrain_step arity"))?;
                *base = b2;
                m = m2;
                v = v2;
                ep_loss += loss[0] as f64;
                nb += 1;
            }
            info!("pretrain epoch {epoch}: loss {:.4}", ep_loss / nb.max(1) as f64);
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // stage 1: warmup (LESS step 1)
    // ------------------------------------------------------------------

    pub fn warmup(&mut self) -> Result<CheckpointSet> {
        if let Some(w) = &self.warmup {
            return Ok(w.clone());
        }
        let dir = self.run_dir().join("warmup");
        let base = self.base()?;
        if dir.join("base.bin").exists() {
            if let Ok(set) = CheckpointSet::load(&dir, self.info.d_base) {
                if set.checkpoints.len() == self.cfg.warmup_epochs {
                    info!("loaded cached warmup checkpoints ({})", set.checkpoints.len());
                    self.stages.cache_hit(Stage::Warmup);
                    self.warmup = Some(set.clone());
                    return Ok(set);
                }
            }
        }
        let t0 = std::time::Instant::now();
        let n_warm = ((self.corpus.len() as f64) * self.cfg.warmup_frac).ceil() as usize;
        let warm_idx = baselines::random_frac(self.corpus.len(), self.cfg.warmup_frac, self.cfg.seed);
        let warm = self.corpus.subset(&warm_idx);
        info!("warmup: {n_warm} samples × {} epochs", self.cfg.warmup_epochs);
        let trainer = Trainer::new(&self.rt, &self.info, &base)?;
        let steps = self.cfg.warmup_epochs * warm.len().div_ceil(self.info.batch_train);
        let sched = Schedule::new(self.cfg.lr, steps, self.cfg.lr_warmup_frac);
        let mut ckpt = Checkpoint::fresh(self.info.d_lora, init_lora(&self.info, self.cfg.seed));
        let mut snaps = Vec::new();
        trainer.train(&warm, &mut ckpt, self.cfg.warmup_epochs, &sched, self.cfg.seed, Some(&mut snaps))?;
        let set = CheckpointSet { base, checkpoints: snaps };
        set.save(&dir)?;
        info!("warmup done in {:.1}s", t0.elapsed().as_secs_f64());
        self.stages.record(Stage::Warmup, t0.elapsed().as_secs_f64());
        self.warmup = Some(set.clone());
        Ok(set)
    }

    // ------------------------------------------------------------------
    // stage 2: gradient features (LESS step 2) — extracted once as fp32
    // ------------------------------------------------------------------

    pub fn projector(&self) -> Projector {
        Projector::new(self.cfg.seed, self.info.d_lora, self.info.proj_dim)
    }

    /// Raw fp32 train features per checkpoint, materialized **densely** —
    /// `n × k × C × 4` bytes resident. This is the explicit small-run
    /// opt-in for analysis harnesses (bin histograms, worker-scaling
    /// benches); the datastore build never calls it — it streams rows
    /// through [`Pipeline::build_datastores`] instead, with peak memory
    /// independent of the corpus size. Model-bits (QLoRA ablation)
    /// applies here: the base weights are quantized for extraction only.
    pub fn train_features_dense(&mut self) -> Result<Vec<FeatureMatrix>> {
        let set = self.warmup()?;
        let proj = self.projector();
        let base_q = quantize_weights(&set.base, self.cfg.model_bits);
        let t0 = std::time::Instant::now();
        let mut feats = Vec::new();
        for (ci, ckpt) in set.checkpoints.iter().enumerate() {
            info!("extracting train features (dense) @ checkpoint {ci}");
            feats.push(extract_train_features(
                &self.rt,
                &self.info,
                &base_q,
                ckpt,
                &self.corpus,
                &proj,
                self.cfg.workers,
            )?);
        }
        info!("train feature extraction: {:.1}s total", t0.elapsed().as_secs_f64());
        self.stages.record(Stage::ExtractTrain, t0.elapsed().as_secs_f64());
        Ok(feats)
    }

    /// Per-checkpoint SGD validation features for one benchmark.
    pub fn val_features(&mut self, bench: Benchmark) -> Result<Vec<FeatureMatrix>> {
        if let Some(f) = self.val_features.get(bench.name()) {
            self.stages.cache_hit(Stage::ExtractVal);
            return Ok(f.clone());
        }
        let set = self.warmup()?;
        let proj = self.projector();
        let base_q = quantize_weights(&set.base, self.cfg.model_bits);
        let samples = validation_samples(bench, &self.world, self.cfg.val_per_task, self.cfg.seed);
        let data = Dataset::encode(samples, &self.tok, self.info.seq);
        let t0 = std::time::Instant::now();
        let mut feats = Vec::new();
        for ckpt in &set.checkpoints {
            feats.push(extract_val_features(
                &self.rt,
                &self.info,
                &base_q,
                ckpt,
                &data,
                &proj,
                self.cfg.workers,
            )?);
        }
        self.stages.record(Stage::ExtractVal, t0.elapsed().as_secs_f64());
        self.val_features.insert(bench.name(), feats.clone());
        Ok(feats)
    }

    // ------------------------------------------------------------------
    // stage 3: quantized datastore (QLESS §3.1) — streaming builder
    // ------------------------------------------------------------------

    /// Build (or reuse) the gradient datastore at one precision; returns
    /// the opened datastore + its measured size. Single-precision alias of
    /// [`Pipeline::build_datastores`].
    pub fn build_datastore(&mut self, precision: Precision) -> Result<(Datastore, u64)> {
        Ok(self.build_datastores(&[precision])?.remove(0))
    }

    /// Build (or reuse) the gradient datastores for **all** requested
    /// precisions in ONE extraction pass — the Table-1 sweep's build path
    /// (`--bits 1,2,4,8,16`).
    ///
    /// Dataflow: per checkpoint, feature rows stream out of
    /// [`extract_train_features_stream`] into a bounded fp32 window
    /// (`--build-mem-budget-mb`), a pool-parallel quantize stage packs the
    /// window at every missing precision (`--build-workers`), and
    /// [`MultiWriter`] writes each packed window through at its final file
    /// offset. Peak builder memory is one window across all precisions —
    /// independent of the corpus size `n` — and the files are
    /// byte-identical to the legacy dense-then-write path.
    ///
    /// Cached files are reused only when their header matches the current
    /// geometry (precision, `n`, `k`, checkpoint count) exactly; a stale
    /// `run_dir` from a different corpus is rebuilt, not silently served.
    /// Stage accounting: the fused pass is recorded under
    /// `Stage::BuildDatastore`, with the peak builder bytes as its io
    /// units.
    pub fn build_datastores(&mut self, precisions: &[Precision]) -> Result<Vec<(Datastore, u64)>> {
        let (n, k) = (self.corpus.len(), self.info.proj_dim);
        let c = self.cfg.warmup_epochs;
        // a crashed ingest (or a manifest left by a different corpus) must
        // never be silently served: roll torn tails back, and clear a
        // manifest whose geometry no longer matches this run before the
        // per-file reuse checks below
        self.reconcile_manifest(precisions, n, k, c)?;
        let mut out: Vec<Option<(Datastore, u64)>> = Vec::new();
        out.resize_with(precisions.len(), || None);
        let mut missing: Vec<(usize, Precision, PathBuf)> = Vec::new();
        for (i, &p) in precisions.iter().enumerate() {
            if precisions[..i].contains(&p) {
                anyhow::bail!("duplicate precision {} in build request", p.label());
            }
            let path = crate::datastore::default_store_path(&self.run_dir(), p);
            if path.exists() {
                match Datastore::open(&path) {
                    Ok(ds) if ds.matches_geometry(p, n, k, c) => {
                        let bytes = ds.file_bytes();
                        info!("reusing cached datastore {}", p.label());
                        self.stages.cache_hit(Stage::BuildDatastore);
                        out[i] = Some((ds, bytes));
                        continue;
                    }
                    _ => {
                        info!(
                            "cached datastore {} does not match the current run \
                             (geometry/precision) — rebuilding",
                            p.label()
                        );
                        std::fs::remove_file(&path).ok();
                    }
                }
            }
            missing.push((i, p, path));
        }

        if !missing.is_empty() {
            let set = self.warmup()?;
            let proj = self.projector();
            let base_q = quantize_weights(&set.base, self.cfg.model_bits);
            let targets: Vec<(Precision, PathBuf)> =
                missing.iter().map(|(_, p, path)| (*p, path.clone())).collect();
            let ps: Vec<Precision> = targets.iter().map(|(p, _)| *p).collect();
            let budget = (self.cfg.build_mem_budget_mb as u64) << 20;
            let window_rows =
                MultiWriter::window_rows_for_budget(k, &ps, budget).min(n.max(1));
            info!(
                "streaming build: {} precision(s) in one extraction pass, \
                 window {window_rows} rows × {} B/row",
                ps.len(),
                MultiWriter::bytes_per_row(k, &ps)
            );
            let t0 = std::time::Instant::now();
            let mut mw =
                MultiWriter::create(&targets, n, k, set.checkpoints.len(), self.cfg.build_workers)?;
            let mut window: Vec<f32> = Vec::with_capacity(window_rows * k);
            for (ci, ckpt) in set.checkpoints.iter().enumerate() {
                info!("streaming build @ checkpoint {ci}");
                mw.begin_checkpoint(ckpt.eta)?;
                window.clear();
                extract_train_features_stream(
                    &self.rt,
                    &self.info,
                    &base_q,
                    ckpt,
                    &self.corpus,
                    &proj,
                    self.cfg.workers,
                    |_start, rows| {
                        fill_windows(&mut window, window_rows * k, rows, |w| mw.append_rows(w))
                    },
                )?;
                if !window.is_empty() {
                    mw.append_rows(&window)?;
                    window.clear();
                }
                mw.end_checkpoint()?;
            }
            let peak = mw.peak_builder_bytes();
            let sizes = mw.finalize()?;
            let secs = t0.elapsed().as_secs_f64();
            self.stages.record(Stage::BuildDatastore, secs);
            // peak builder bytes are a high-water mark, not a counter — a
            // second build in the same process must not sum with the first
            self.stages.max_units(Stage::BuildDatastore, peak);
            info!(
                "streaming build done in {secs:.1}s (peak builder memory {})",
                crate::util::table::human_bytes(peak)
            );
            for ((i, p, path), bytes) in missing.into_iter().zip(sizes) {
                info!("datastore {}: {}", p.label(), crate::util::table::human_bytes(bytes));
                out[i] = Some((Datastore::open(&path)?, bytes));
            }
        }
        Ok(out.into_iter().map(|o| o.expect("every requested precision resolved")).collect())
    }

    /// Reconcile the run directory's generation manifest with this run's
    /// geometry before reusing or rebuilding datastores: repair any
    /// crash-torn ingest tail ([`repair_run_dir`]) and, when the manifest
    /// describes a different world (corpus size, projection dim or
    /// checkpoint count), delete its segments and the manifest itself so
    /// the per-file geometry checks rebuild from scratch.
    fn reconcile_manifest(
        &self,
        precisions: &[Precision],
        n: usize,
        k: usize,
        c: usize,
    ) -> Result<()> {
        let run_dir = self.run_dir();
        let Some(m) = repair_run_dir(&run_dir, precisions)? else {
            return Ok(());
        };
        if m.base_rows == n as u64 && m.k == k as u64 && m.n_checkpoints == c as u32 {
            return Ok(());
        }
        info!("stale manifest in {run_dir:?} (different geometry) — clearing segments");
        for &p in precisions {
            let base = default_store_path(&run_dir, p);
            for seg in &m.segments {
                let _ = std::fs::remove_file(segment_store_path(&base, seg.generation));
            }
        }
        std::fs::remove_file(Manifest::path_in(&run_dir)).ok();
        Ok(())
    }

    // ------------------------------------------------------------------
    // stage 3b: incremental ingest (live datastore growth)
    // ------------------------------------------------------------------

    /// Open this run's **live** datastore at one precision (base file +
    /// every ingested segment) for scoring and serving.
    pub fn open_live(&self, precision: Precision) -> Result<LiveStore> {
        LiveStore::open(&default_store_path(&self.run_dir(), precision))
    }

    /// Append `n_new` fresh corpus rows to this run's existing datastores
    /// at **all** requested precisions in ONE extraction pass — the
    /// incremental counterpart of [`Pipeline::build_datastores`]
    /// (`qless ingest --ingest-rows N`).
    ///
    /// Dataflow mirrors the streaming build: a deterministic corpus
    /// extension for the next generation ([`crate::corpus::extend_corpus`])
    /// is generated and encoded — **only** the new samples; the stored
    /// corpus is never copied or re-extracted — gradient rows stream out
    /// of [`extract_train_features_stream`] through the bounded window
    /// into a [`SegmentWriter`], which quantizes each window at every
    /// precision, writes self-contained segment files next to the bases,
    /// and publishes them with a generation bump. No pre-existing byte is
    /// touched; a crash at any point leaves the previous generation
    /// intact ([`repair_run_dir`] runs first to clear any earlier crash's
    /// leftovers). Ingesting a precision *subset* of the run is refused —
    /// the manifest covers every precision in the directory. A running
    /// `qless serve` session over the same run directory picks the new
    /// generation up on its next batch, without restart.
    pub fn ingest_datastores(
        &mut self,
        precisions: &[Precision],
        n_new: usize,
    ) -> Result<IngestReport> {
        anyhow::ensure!(n_new > 0, "ingest needs at least one new row (--ingest-rows N)");
        let (k, c) = (self.info.proj_dim, self.cfg.warmup_epochs);
        let run_dir = self.run_dir();
        repair_run_dir(&run_dir, precisions)?;
        for &p in precisions {
            let path = default_store_path(&run_dir, p);
            let ds = Datastore::open(&path).with_context(|| {
                format!(
                    "ingest needs an existing {} datastore in {run_dir:?} \
                     (run `qless extract` first)",
                    p.label()
                )
            })?;
            anyhow::ensure!(
                ds.matches_geometry(p, self.corpus.len(), k, c),
                "cached {} datastore does not match this run's geometry \
                 ({} rows × k={k} × {c} checkpoints) — rebuild before ingesting",
                p.label(),
                self.corpus.len()
            );
        }
        let set = self.warmup()?;
        let mut sw = SegmentWriter::create(&run_dir, precisions, n_new, self.cfg.build_workers)?;
        // the segment inherits the BASE stores' η; the warmup checkpoints
        // driving extraction must be the ones that built those stores
        for (ci, ckpt) in set.checkpoints.iter().enumerate() {
            anyhow::ensure!(
                sw.etas()[ci].to_bits() == ckpt.eta.to_bits(),
                "warmup checkpoint {ci} (η={}) does not match the base datastores (η={}) — \
                 the run_dir's warmup cache and stores are out of sync; rebuild",
                ckpt.eta,
                sw.etas()[ci]
            );
        }
        let generation = sw.generation();
        let start_row = sw.start_row();
        info!(
            "ingest: generation {generation}, {n_new} rows at {start_row}.. across {} precision(s)",
            precisions.len()
        );
        // only the NEW samples are encoded and extracted — the stored
        // corpus is never copied or re-extracted; global row ids come
        // from `start_row` (sample ids) and segment-local row order
        let ext = crate::corpus::extend_corpus(
            n_new,
            self.cfg.seed,
            generation,
            start_row,
            &self.tok,
            self.info.seq,
        );
        let ext_ds = Dataset::encode(ext, &self.tok, self.info.seq);
        let proj = self.projector();
        let base_q = quantize_weights(&set.base, self.cfg.model_bits);
        let budget = (self.cfg.build_mem_budget_mb as u64) << 20;
        let window_rows =
            MultiWriter::window_rows_for_budget(k, precisions, budget).min(n_new.max(1));
        let t0 = std::time::Instant::now();
        let mut window: Vec<f32> = Vec::with_capacity(window_rows * k);
        for (ci, ckpt) in set.checkpoints.iter().enumerate() {
            info!("ingest @ checkpoint {ci}");
            sw.begin_checkpoint()?;
            window.clear();
            extract_train_features_stream(
                &self.rt,
                &self.info,
                &base_q,
                ckpt,
                &ext_ds,
                &proj,
                self.cfg.workers,
                |_start, rows| {
                    fill_windows(&mut window, window_rows * k, rows, |w| sw.append_rows(w))
                },
            )?;
            if !window.is_empty() {
                sw.append_rows(&window)?;
                window.clear();
            }
            sw.end_checkpoint()?;
        }
        let (seg, _, sizes) = sw.finalize()?;
        let secs = t0.elapsed().as_secs_f64();
        self.stages.record(Stage::Ingest, secs);
        self.stages.add_units(Stage::Ingest, n_new as u64);
        info!(
            "ingest done in {secs:.1}s: generation {} covers rows {}..{}",
            seg.generation,
            seg.start_row,
            seg.start_row + seg.rows
        );
        Ok(IngestReport {
            generation: seg.generation,
            start_row,
            rows: n_new,
            segment_bytes: sizes,
        })
    }

    /// The live corpus' sample metadata: the base corpus plus every
    /// ingested generation's extension samples, regenerated
    /// deterministically from the live store's member map — so selection
    /// composition (Fig. 5) works over ingested rows without persisting
    /// any extra corpus file.
    pub fn samples_with_extensions(
        &self,
        live: &LiveStore,
    ) -> Result<Vec<crate::corpus::Sample>> {
        anyhow::ensure!(
            live.members()[0].ds.n_samples() == self.corpus.len(),
            "live store base ({} rows) does not match this run's corpus ({} rows)",
            live.members()[0].ds.n_samples(),
            self.corpus.len()
        );
        let mut all = self.corpus.samples.clone();
        for m in live.members().iter().skip(1) {
            all.extend(crate::corpus::extend_corpus(
                m.ds.n_samples(),
                self.cfg.seed,
                m.generation,
                m.start_row,
                &self.tok,
                self.info.seq,
            ));
        }
        Ok(all)
    }

    /// Influence scores of every **live** row for every benchmark — the
    /// live-store counterpart of [`Pipeline::influence_scores_all`]: all
    /// benchmarks' validation tasks ride ONE streamed pass over base +
    /// segments ([`score_live_tasks`]). Native kernels only; with
    /// `cfg.xla_score` set the scan falls back to native with a warning.
    pub fn influence_scores_all_live(
        &mut self,
        live: &LiveStore,
    ) -> Result<BTreeMap<&'static str, Vec<f32>>> {
        if self.cfg.xla_score {
            warn_!("XLA scoring is not plumbed through live stores; using native kernels");
        }
        let mut vals: Vec<Vec<FeatureMatrix>> = Vec::new();
        for bench in Benchmark::ALL {
            vals.push(self.val_features(bench)?);
        }
        let refs: Vec<&[FeatureMatrix]> = vals.iter().map(|v| v.as_slice()).collect();
        let opts = ScoreOpts { use_xla: false, ..self.score_opts() };
        let t0 = std::time::Instant::now();
        let (per_task, stats) = score_live_tasks(live, &refs, opts)?;
        self.stages.record(Stage::Score, t0.elapsed().as_secs_f64());
        self.stages.add_units(Stage::Score, stats.shards_read as u64);
        info!(
            "live multi-query scan: {} benchmarks × {} rows (generation {}) in {} shard reads",
            stats.tasks,
            live.n_rows(),
            live.generation(),
            stats.shards_read
        );
        let mut out = BTreeMap::new();
        for (bench, scores) in Benchmark::ALL.iter().zip(per_task) {
            out.insert(bench.name(), scores);
        }
        Ok(out)
    }

    /// Compute-constrained cascade over this run's **live** stores, for
    /// every benchmark (`--cascade PROBE,RERANK --cascade-mult C`): one
    /// fused pass probes every row at the cheap `probe` precision, keeps
    /// each benchmark's top `C · k_sel` candidate rows, and re-scores
    /// only those rows at the `rerank` precision via random access — so
    /// the final top-`k_sel` carries rerank-precision scores while the
    /// bulk of the I/O happens at probe cost. Both precisions must exist
    /// in the run directory (build with `--bits` listing them). With
    /// `C · k_sel >=` the live row count the result is byte-identical to
    /// an exhaustive rerank-precision scan. Returns each benchmark's
    /// final top list (score desc, index asc on ties) plus the combined
    /// probe + rerank scan stats.
    pub fn cascade_scores_all(
        &mut self,
        probe: Precision,
        rerank: Precision,
        mult: usize,
        k_sel: usize,
    ) -> Result<(BTreeMap<&'static str, Vec<(usize, f32)>>, ScanStats)> {
        if self.cfg.xla_score {
            warn_!("XLA scoring is not plumbed through cascades; using native kernels");
        }
        let probe_live = self.open_live(probe).with_context(|| {
            format!(
                "opening the cascade's {} probe store — build the run with --bits \
                 listing every cascade precision",
                probe.label()
            )
        })?;
        let rerank_live = self.open_live(rerank).with_context(|| {
            format!(
                "opening the cascade's {} rerank store — build the run with --bits \
                 listing every cascade precision",
                rerank.label()
            )
        })?;
        let mut vals: Vec<Vec<FeatureMatrix>> = Vec::new();
        for bench in Benchmark::ALL {
            vals.push(self.val_features(bench)?);
        }
        let refs: Vec<&[FeatureMatrix]> = vals.iter().map(|v| v.as_slice()).collect();
        let opts = CascadeOpts {
            k: k_sel,
            mult,
            scan: ScoreOpts { use_xla: false, ..self.score_opts() },
        };
        let t0 = std::time::Instant::now();
        let outcome = cascade_live_tasks(&probe_live, &rerank_live, &refs, opts)?;
        let pass = outcome.combined_pass();
        self.stages.record(Stage::Score, t0.elapsed().as_secs_f64());
        self.stages.add_units(Stage::Score, pass.shards_read as u64);
        let exhaustive = cascade::exhaustive_scan_bytes(rerank_live.header(), rerank_live.n_rows());
        info!(
            "cascade scan: {} benchmarks, {} probe → {} rerank, {} of {} rows reranked, \
             {} read vs {} exhaustive",
            refs.len(),
            probe.label(),
            rerank.label(),
            outcome.reranked_rows,
            probe_live.n_rows(),
            crate::util::table::human_bytes(pass.bytes_read),
            crate::util::table::human_bytes(exhaustive)
        );
        let mut out = BTreeMap::new();
        for (bench, top) in Benchmark::ALL.iter().zip(outcome.top) {
            out.insert(bench.name(), top);
        }
        Ok((out, pass))
    }

    /// Sub-linear indexed selection over this run's live store (`qless
    /// score --nprobe P`): probe the `.qidx` sidecar's packed sign
    /// centroids, scan only each benchmark's top-`P` clusters, and return
    /// the final top-`k_sel` per benchmark. `nprobe >=` the cluster count
    /// degrades gracefully to full coverage, which is byte-identical to
    /// the exhaustive scan ([`index_scan_live_tasks`]). Also returns the
    /// combined probe+scan stats and the candidate-row count, so callers
    /// can report the row-traffic reduction against `live.n_rows()`.
    pub fn indexed_scores_all(
        &mut self,
        live: &LiveStore,
        idx: &QuantIndex,
        nprobe: usize,
        k_sel: usize,
    ) -> Result<(BTreeMap<&'static str, Vec<(usize, f32)>>, ScanStats, usize)> {
        if self.cfg.xla_score {
            warn_!("XLA scoring is not plumbed through the index; using native kernels");
        }
        let mut vals: Vec<Vec<FeatureMatrix>> = Vec::new();
        for bench in Benchmark::ALL {
            vals.push(self.val_features(bench)?);
        }
        let refs: Vec<&[FeatureMatrix]> = vals.iter().map(|v| v.as_slice()).collect();
        let opts = IndexOpts {
            k: k_sel,
            nprobe,
            scan: ScoreOpts { use_xla: false, ..self.score_opts() },
        };
        let t0 = std::time::Instant::now();
        let outcome = index_scan_live_tasks(live, idx, &refs, &opts)?;
        let pass = outcome.combined_pass();
        self.stages.record(Stage::Score, t0.elapsed().as_secs_f64());
        self.stages.add_units(Stage::Score, pass.shards_read as u64);
        info!(
            "indexed scan: {} benchmarks, {} of {} clusters probed, {} of {} rows scanned",
            refs.len(),
            crate::influence::effective_nprobe(idx, nprobe),
            idx.n_clusters(),
            outcome.scanned_rows,
            live.n_rows()
        );
        let scanned = outcome.scanned_rows;
        let mut out = BTreeMap::new();
        for (bench, top) in Benchmark::ALL.iter().zip(outcome.top) {
            out.insert(bench.name(), top);
        }
        Ok((out, pass, scanned))
    }

    /// Index × cascade composition (`--nprobe P --cascade PROBE,RERANK`):
    /// the sidecar narrows the probe stage to the top-`P` clusters, the
    /// cascade's rerank re-scores the surviving candidates at the high
    /// precision ([`index_cascade_live_tasks`]). Both sibling stores must
    /// exist; the sidecar indexes the probe-precision store.
    pub fn indexed_cascade_scores_all(
        &mut self,
        probe: Precision,
        rerank: Precision,
        idx: &QuantIndex,
        mult: usize,
        k_sel: usize,
        nprobe: usize,
    ) -> Result<(BTreeMap<&'static str, Vec<(usize, f32)>>, ScanStats)> {
        if self.cfg.xla_score {
            warn_!("XLA scoring is not plumbed through the index; using native kernels");
        }
        let probe_live = self.open_live(probe)?;
        let rerank_live = self.open_live(rerank)?;
        let mut vals: Vec<Vec<FeatureMatrix>> = Vec::new();
        for bench in Benchmark::ALL {
            vals.push(self.val_features(bench)?);
        }
        let refs: Vec<&[FeatureMatrix]> = vals.iter().map(|v| v.as_slice()).collect();
        let opts = CascadeOpts {
            k: k_sel,
            mult,
            scan: ScoreOpts { use_xla: false, ..self.score_opts() },
        };
        let t0 = std::time::Instant::now();
        let outcome = index_cascade_live_tasks(&probe_live, &rerank_live, idx, &refs, &opts, nprobe)?;
        let pass = outcome.combined_pass();
        self.stages.record(Stage::Score, t0.elapsed().as_secs_f64());
        self.stages.add_units(Stage::Score, pass.shards_read as u64);
        let mut out = BTreeMap::new();
        for (bench, top) in Benchmark::ALL.iter().zip(outcome.top) {
            out.insert(bench.name(), top);
        }
        Ok((out, pass))
    }

    // ------------------------------------------------------------------
    // stage 4+5: score & select (QLESS §3.2, LESS step 3)
    // ------------------------------------------------------------------

    fn score_opts(&self) -> ScoreOpts {
        ScoreOpts {
            use_xla: self.cfg.xla_score,
            shard_rows: self.cfg.shard_rows,
            mem_budget_mb: self.cfg.mem_budget_mb,
        }
    }

    /// Influence scores of every corpus sample for one benchmark at one
    /// precision. The scan streams datastore shards under the config's
    /// memory budget (`--shard-rows` / `--mem-budget-mb`).
    pub fn influence_scores(&mut self, ds: &Datastore, bench: Benchmark) -> Result<Vec<f32>> {
        let vals = self.val_features(bench)?;
        let opts = self.score_opts();
        let t0 = std::time::Instant::now();
        let (mut per_task, stats) =
            score_datastore_tasks(ds, &[&vals], opts, Some((&self.rt, &self.info)))?;
        self.stages.record(Stage::Score, t0.elapsed().as_secs_f64());
        self.stages.add_units(Stage::Score, stats.shards_read as u64);
        Ok(per_task.swap_remove(0))
    }

    /// Influence scores of every corpus sample for **every** benchmark.
    /// With `cfg.multi_scan` (the default) all benchmarks' validation
    /// tasks ride ONE streamed pass over the datastore — shared shard
    /// traversal, per-task accumulators — so the Score stage's I/O units
    /// (shard reads) are those of a single scan, not one per benchmark.
    /// With `multi_scan = false` this degrades to one pass per benchmark.
    pub fn influence_scores_all(
        &mut self,
        ds: &Datastore,
    ) -> Result<BTreeMap<&'static str, Vec<f32>>> {
        let mut out = BTreeMap::new();
        if !self.cfg.multi_scan {
            for bench in Benchmark::ALL {
                out.insert(bench.name(), self.influence_scores(ds, bench)?);
            }
            return Ok(out);
        }
        let mut vals: Vec<Vec<FeatureMatrix>> = Vec::new();
        for bench in Benchmark::ALL {
            vals.push(self.val_features(bench)?);
        }
        let refs: Vec<&[FeatureMatrix]> = vals.iter().map(|v| v.as_slice()).collect();
        let opts = self.score_opts();
        let t0 = std::time::Instant::now();
        let (per_task, stats) =
            score_datastore_tasks(ds, &refs, opts, Some((&self.rt, &self.info)))?;
        self.stages.record(Stage::Score, t0.elapsed().as_secs_f64());
        self.stages.add_units(Stage::Score, stats.shards_read as u64);
        info!(
            "multi-query scan: {} benchmarks in {} shard reads (one datastore pass)",
            stats.tasks, stats.shards_read
        );
        for (bench, scores) in Benchmark::ALL.iter().zip(per_task) {
            out.insert(bench.name(), scores);
        }
        Ok(out)
    }

    // ------------------------------------------------------------------
    // stage 6+7: fine-tune & evaluate
    // ------------------------------------------------------------------

    /// LoRA fine-tune the pretrained base on a subset; returns the adapter
    /// and the per-epoch loss curve.
    pub fn finetune(&mut self, indices: &[usize], seed: u64) -> Result<(Vec<f32>, Vec<f64>)> {
        let base = self.base()?;
        let t0 = std::time::Instant::now();
        let sub = self.corpus.subset(indices);
        let trainer = Trainer::new(&self.rt, &self.info, &base)?;
        let steps = self.cfg.finetune_epochs * sub.len().div_ceil(self.info.batch_train);
        let sched = Schedule::new(self.cfg.lr, steps, self.cfg.lr_warmup_frac);
        let mut ckpt = Checkpoint::fresh(self.info.d_lora, init_lora(&self.info, seed));
        let report = trainer.train(&sub, &mut ckpt, self.cfg.finetune_epochs, &sched, seed, None)?;
        self.stages.record(Stage::Finetune, t0.elapsed().as_secs_f64());
        Ok((ckpt.lora, report.epoch_losses))
    }

    pub fn evaluate_lora(&mut self, lora: &[f32]) -> Result<BenchScores> {
        let base = self.base()?;
        let t0 = std::time::Instant::now();
        let scores = evaluate(
            &self.rt,
            &self.info,
            &base,
            lora,
            &self.world,
            self.cfg.eval_per_task,
            self.cfg.seed,
        )?;
        self.stages.record(Stage::Evaluate, t0.elapsed().as_secs_f64());
        Ok(scores)
    }

    // ------------------------------------------------------------------
    // full method runs (one Table-1 row)
    // ------------------------------------------------------------------

    pub fn run_method(&mut self, method: Method) -> Result<MethodResult> {
        let label = method.label(&self.cfg);
        info!("=== method: {label} ===");
        let mut result = MethodResult {
            label: label.clone(),
            scores: BTreeMap::new(),
            average: 0.0,
            storage_bytes: 0,
            distributions: BTreeMap::new(),
            selections: BTreeMap::new(),
            loss_curves: BTreeMap::new(),
        };
        match method {
            Method::Random100 | Method::RandomFrac => {
                let indices = match method {
                    Method::Random100 => baselines::all_indices(self.corpus.len()),
                    _ => baselines::random_frac(
                        self.corpus.len(),
                        self.cfg.select_frac,
                        self.cfg.seed ^ 0xBA5E11,
                    ),
                };
                let (lora, curve) = self.finetune(&indices, self.cfg.seed)?;
                let scores = self.evaluate_lora(&lora)?;
                for bench in Benchmark::ALL {
                    result.scores.insert(bench.name(), scores.get(bench));
                    result
                        .distributions
                        .insert(bench.name(), SourceDistribution::of(&self.corpus.samples, &indices));
                    result.loss_curves.insert(bench.name(), curve.clone());
                    result.selections.insert(bench.name(), indices.clone());
                }
            }
            Method::Qless(precision) => {
                let (ds, bytes) = self.build_datastore(precision)?;
                result.storage_bytes = bytes;
                // one streamed datastore pass scores every benchmark
                let all_scores = self.influence_scores_all(&ds)?;
                for bench in Benchmark::ALL {
                    let scores = &all_scores[bench.name()];
                    let t_sel = std::time::Instant::now();
                    let sel = select_top_frac(scores, self.cfg.select_frac);
                    self.stages.record(Stage::Select, t_sel.elapsed().as_secs_f64());
                    let dist = SourceDistribution::of(&self.corpus.samples, &sel);
                    info!("{label} / {bench}: selected {} — {}", sel.len(), dist.render());
                    let (lora, curve) = self.finetune(&sel, self.cfg.seed)?;
                    let bench_scores = self.evaluate_lora(&lora)?;
                    result.scores.insert(bench.name(), bench_scores.get(bench));
                    result.distributions.insert(bench.name(), dist);
                    result.loss_curves.insert(bench.name(), curve);
                    result.selections.insert(bench.name(), sel);
                }
            }
        }
        result.average =
            result.scores.values().sum::<f64>() / result.scores.len().max(1) as f64;
        info!(
            "{label}: avg {:.2}% {:?}",
            result.average * 100.0,
            result.scores.iter().map(|(k, v)| format!("{k}={:.1}%", v * 100.0)).collect::<Vec<_>>()
        );
        Ok(result)
    }
}
