//! Selection baselines (paper §4.1): random-p% and random-100%.
//! The LESS baseline itself is QLESS at 16-bit (identity quantization) —
//! exactness is preserved through the bf16 datastore, so it shares the
//! whole pipeline rather than being a separate implementation.

use crate::util::Rng;

/// Random p% selection (the paper's lower-bound baseline). Seeded so each
/// trial draws a different subset while staying reproducible.
pub fn random_frac(n: usize, frac: f64, seed: u64) -> Vec<usize> {
    assert!((0.0..=1.0).contains(&frac));
    let k = ((n as f64) * frac).ceil().max(1.0) as usize;
    let mut rng = Rng::new(seed).fork(0x4A_0D0);
    let mut idx = rng.sample_indices(n, k.min(n));
    idx.sort_unstable();
    idx
}

/// The full dataset (random 100%).
pub fn all_indices(n: usize) -> Vec<usize> {
    (0..n).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_frac_sizes() {
        assert_eq!(random_frac(100, 0.05, 1).len(), 5);
        assert_eq!(random_frac(100, 0.0, 1).len(), 1);
        assert_eq!(random_frac(10, 1.0, 1).len(), 10);
    }

    #[test]
    fn random_frac_distinct_sorted_in_range() {
        let s = random_frac(1000, 0.1, 2);
        assert!(s.windows(2).all(|w| w[0] < w[1]));
        assert!(s.iter().all(|&i| i < 1000));
    }

    #[test]
    fn seeds_give_different_subsets() {
        assert_ne!(random_frac(1000, 0.05, 1), random_frac(1000, 0.05, 2));
        assert_eq!(random_frac(1000, 0.05, 3), random_frac(1000, 0.05, 3));
    }

    #[test]
    fn all_indices_complete() {
        assert_eq!(all_indices(4), vec![0, 1, 2, 3]);
    }
}
