//! `qless` — CLI entrypoint for the QLESS reproduction.
//!
//! See `qless --help` (config::cli::USAGE) for the command list. All heavy
//! lifting lives in the library; this binary parses arguments, dispatches,
//! and renders results.

use anyhow::Result;

use qless::config::cli::{parse_args, usage_for, Cli, USAGE};
use qless::corpus::source_counts;
use qless::eval::Benchmark;
use qless::pipeline::{Method, Pipeline};
use qless::quant::Precision;
use qless::select::{select_top_frac, SourceDistribution};
use qless::service::{Client, MetricsReply, StatsReply};
use qless::util::obs;
use qless::util::obs::SpanRecord;
use qless::util::table::{human_bytes, pct, Table};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = match parse_args(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e:#}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&cli) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(cli: &Cli) -> Result<()> {
    match cli.command.as_str() {
        "help" => {
            // `qless <cmd> --help` routes here with the command as the
            // positional, so serve prints its own flag set
            let topic = cli.positional.first().map(String::as_str).unwrap_or("");
            println!("{}", usage_for(topic));
            Ok(())
        }
        "serve" => serve(cli),
        "stats" => stats(cli),
        "list-artifacts" => list_artifacts(cli),
        "gen-corpus" => gen_corpus(cli),
        "warmup" => {
            let mut pipe = Pipeline::new(cli.config.clone())?;
            let set = pipe.warmup()?;
            println!(
                "warmup complete: {} checkpoints in {}/warmup",
                set.checkpoints.len(),
                cli.config.run_dir
            );
            Ok(())
        }
        "extract" => {
            // `--bits 1,2,4,8,16` builds every precision in ONE extraction
            // pass (streaming builder); a single value builds just that one
            let mut pipe = Pipeline::new(cli.config.clone())?;
            let ps = cli.config.precisions()?;
            let stores = pipe.build_datastores(&ps)?;
            for (p, (ds, bytes)) in ps.iter().zip(&stores) {
                println!(
                    "datastore: {} samples × {} dims × {} checkpoints at {} = {}",
                    ds.n_samples(),
                    ds.header.k,
                    ds.n_checkpoints(),
                    p.label(),
                    human_bytes(*bytes)
                );
            }
            let build = pipe.stages.cost(qless::pipeline::Stage::BuildDatastore);
            if build.runs > 0 {
                // cache hits were reused, not built — report only what the
                // fused pass actually wrote
                println!(
                    "one fused pass: {} precision(s) built, {} reused from cache, \
                     peak builder memory {}",
                    ps.len() - build.cache_hits as usize,
                    build.cache_hits,
                    human_bytes(build.io_units)
                );
            }
            Ok(())
        }
        "ingest" => ingest(cli),
        "reindex" => reindex(cli),
        "score" | "select" => score_select(cli),
        "eval" => eval_baseline(cli),
        "decode-demo" => decode_demo(cli),
        "pipeline" => run_pipeline(cli),
        "xp" => {
            let id = cli
                .positional
                .first()
                .ok_or_else(|| anyhow::anyhow!("xp needs an experiment id\n\n{USAGE}"))?;
            qless::experiments::run(id, &cli.config, cli.fast)
        }
        other => anyhow::bail!("unknown command '{other}'\n\n{USAGE}"),
    }
}

/// `qless serve` — start the resident influence query service over the
/// configured datastore and block until a client sends `shutdown`.
/// With `--local-workers N` (or `--worker-addrs`) it starts the
/// scatter-gather coordinator instead: same wire protocol, same
/// answers, N workers splitting every scan.
fn serve(cli: &Cli) -> Result<()> {
    let cfg = &cli.config;
    if cli.traces {
        // span collection is off by default (a pure metrics scrape costs
        // nothing); --traces turns the in-process ring on so `qless stats
        // --traces` can fetch stitched per-query trees
        obs::set_tracing(true);
    }
    let path = if cfg.datastore.is_empty() {
        let p = Precision::new(cfg.bits, cfg.scheme)?;
        qless::datastore::default_store_path(std::path::Path::new(&cfg.run_dir), p)
    } else {
        std::path::PathBuf::from(&cfg.datastore)
    };
    if cfg.local_workers > 0 || !cfg.worker_addrs.is_empty() {
        // in local mode each worker binds its own ephemeral port; the
        // coordinator takes the configured serve address
        let mut worker_opts = cfg.serve_opts();
        worker_opts.addr = "127.0.0.1:0".into();
        let co = if cfg.local_workers > 0 {
            qless::service::Coordinator::start_local(
                &path,
                cfg.local_workers,
                worker_opts,
                cfg.coordinator_opts(),
            )?
        } else {
            qless::service::Coordinator::start(cfg.coordinator_opts())?
        };
        println!(
            "qless serve: coordinator on {} over {} worker(s){}",
            co.addr(),
            co.local_workers().len().max(cfg.worker_addr_list().len()),
            if cfg.local_workers > 0 {
                format!(" (local, from {})", path.display())
            } else {
                String::new()
            },
        );
        println!(
            "try: echo '{{\"op\":\"ping\",\"id\":1}}' | nc {} {}",
            co.addr().ip(),
            co.addr().port()
        );
        return co.join();
    }
    let server = qless::service::Server::start(&path, cfg.serve_opts())?;
    let h = server.header();
    println!(
        "qless serve: listening on {} — {} samples × k={} × {} checkpoints at {} \
         (generation {:#x}) from {}",
        server.addr(),
        h.n_samples,
        h.k,
        h.n_checkpoints,
        h.precision.label(),
        server.generation(),
        path.display(),
    );
    println!(
        "try: echo '{{\"op\":\"ping\",\"id\":1}}' | nc {} {}",
        server.addr().ip(),
        server.addr().port()
    );
    server.join()
}

/// `qless stats` — scrape a running server's `stats` + `metrics` verbs
/// and render them as tables. `--watch N` re-scrapes every N seconds
/// until interrupted; `--traces` also dumps the server's recent span
/// ring (populated when the server runs with `--traces`). Against a
/// coordinator the tables show fleet-merged registries plus a
/// per-worker breakdown.
fn stats(cli: &Cli) -> Result<()> {
    let cfg = &cli.config;
    loop {
        let mut c = Client::connect(&cfg.serve_addr)?;
        let s = c.stats_detail(true)?;
        let m = c.metrics(cli.traces, false)?;
        render_scrape(&s, &m);
        if cfg.watch == 0 {
            return Ok(());
        }
        println!();
        std::thread::sleep(std::time::Duration::from_secs(cfg.watch));
    }
}

fn render_scrape(s: &StatsReply, m: &MetricsReply) {
    println!(
        "qless stats: generation {:#x} — {} rows × k={} × {} checkpoints at {} bits",
        s.generation, s.n_samples, s.k, s.checkpoints, s.bits
    );
    let st = &s.stats;
    let mut t = Table::new(
        "service totals",
        &["queries", "batches", "passes", "score-cache", "shard-cache", "rows scored", "reloads"],
    );
    t.row(vec![
        st.queries.to_string(),
        st.batches.to_string(),
        st.fused_passes.to_string(),
        format!("{} hits", st.score_cache_hits),
        format!("{} hits / {}", st.shard_cache_hits, human_bytes(st.shard_cache_bytes)),
        st.rows_scored.to_string(),
        st.reloads.to_string(),
    ]);
    print!("{}", t.render());
    if let Some(ws) = &s.per_worker {
        let mut t = Table::new(
            "per-worker",
            &["addr", "generation", "rows", "queries", "rows scored"],
        );
        for w in ws {
            t.row(vec![
                w.addr.clone(),
                format!("{:#x}", w.generation),
                w.n_samples.to_string(),
                w.stats.queries.to_string(),
                w.stats.rows_scored.to_string(),
            ]);
        }
        print!("{}", t.render());
    }
    let snap = &m.snapshot;
    if !snap.counters.is_empty() || !snap.gauges.is_empty() {
        let mut t = Table::new("counters & gauges", &["name", "value"]);
        for (k, v) in &snap.counters {
            t.row(vec![k.clone(), v.to_string()]);
        }
        for (k, v) in &snap.gauges {
            t.row(vec![format!("{k} (gauge)"), v.to_string()]);
        }
        print!("{}", t.render());
    }
    if !snap.histos.is_empty() {
        let mut t =
            Table::new("latency histograms (µs)", &["name", "count", "p50", "p95", "p99", "mean"]);
        for (k, h) in &snap.histos {
            let mean = if h.count > 0 { h.sum / h.count } else { 0 };
            t.row(vec![
                k.clone(),
                h.count.to_string(),
                h.quantile(0.5).to_string(),
                h.quantile(0.95).to_string(),
                h.quantile(0.99).to_string(),
                mean.to_string(),
            ]);
        }
        print!("{}", t.render());
    }
    if let Some(spans) = &m.traces {
        if spans.is_empty() {
            println!("traces: none recorded (run the server with --traces)");
        } else {
            println!("traces: {} recent span(s)", spans.len());
            for sp in spans {
                println!(
                    "  [{:>10x}] {:>8}µs @{:>8}µs  {}{}",
                    sp.trace,
                    sp.dur_us,
                    sp.start_us,
                    "  ".repeat(span_depth(spans, sp)),
                    sp.name,
                );
            }
        }
    }
}

/// Indentation depth of `sp` inside the fetched span set: hops to the
/// nearest ancestor whose parent is absent (capped — worker-reported
/// parents may fall outside the ring).
fn span_depth(spans: &[SpanRecord], sp: &SpanRecord) -> usize {
    let mut depth = 0usize;
    let mut parent = sp.parent;
    while parent != 0 && depth < 8 {
        match spans.iter().find(|s| s.id == parent) {
            Some(p) => {
                parent = p.parent;
                depth += 1;
            }
            None => break,
        }
    }
    depth
}

fn list_artifacts(cli: &Cli) -> Result<()> {
    let rt = qless::runtime::Runtime::new(std::path::Path::new(&cli.config.artifacts))?;
    println!("platform: {}", rt.platform());
    let mut t = Table::new("models", &["model", "d_base", "d_lora", "k", "seq", "artifacts"]);
    for (name, m) in &rt.manifest.models {
        t.row(vec![
            name.clone(),
            m.d_base.to_string(),
            m.d_lora.to_string(),
            m.proj_dim.to_string(),
            m.seq.to_string(),
            m.artifacts.len().to_string(),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn gen_corpus(cli: &Cli) -> Result<()> {
    let pipe = Pipeline::new(cli.config.clone())?;
    let counts = source_counts(&pipe.corpus.samples);
    let mut t = Table::new(
        &format!("corpus ({} samples, seed {})", pipe.corpus.len(), cli.config.seed),
        &["source", "count", "fraction", "example"],
    );
    for (src, count) in counts {
        let ex = pipe
            .corpus
            .samples
            .iter()
            .find(|s| s.source == src)
            .map(|s| format!("{} → {}", s.prompt, s.answer))
            .unwrap_or_default();
        t.row(vec![
            src.to_string(),
            count.to_string(),
            format!("{:.1}%", 100.0 * count as f64 / pipe.corpus.len() as f64),
            ex.chars().take(60).collect(),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

/// `qless ingest` — append `--ingest-rows` fresh corpus rows to the run's
/// existing datastores (every configured precision, one extraction pass)
/// as a new generation. Pre-existing bytes are untouched; a running
/// `qless serve` over the same run-dir picks the new generation up live.
fn ingest(cli: &Cli) -> Result<()> {
    let n_new = cli.config.ingest_rows;
    anyhow::ensure!(n_new > 0, "ingest needs --ingest-rows N (> 0)\n\n{USAGE}");
    let mut pipe = Pipeline::new(cli.config.clone())?;
    let ps = cli.config.precisions()?;
    let report = pipe.ingest_datastores(&ps, n_new)?;
    println!(
        "ingest: generation {} appended rows {}..{} to {} precision(s)",
        report.generation,
        report.start_row,
        report.start_row + report.rows,
        ps.len()
    );
    for (p, bytes) in ps.iter().zip(&report.segment_bytes) {
        println!("  {} segment: {}", p.label(), human_bytes(*bytes));
    }
    Ok(())
}

/// `qless reindex` — (re)build the Hamming-clustered IVF sidecar
/// (`<stem>.qidx`) next to each of the run's precision stores, from the
/// full live row set (base + every ingested generation). `--nclusters 0`
/// (the default) derives ⌈√n⌉. The write is atomic; a running `qless
/// serve` over the same run dir picks the fresh sidecar up on its next
/// indexed query.
fn reindex(cli: &Cli) -> Result<()> {
    let run_dir = std::path::Path::new(&cli.config.run_dir);
    let opts = qless::datastore::IndexBuildOpts {
        n_clusters: cli.config.nclusters,
        max_iters: 0,
    };
    let ps = cli.config.precisions()?;
    for &p in &ps {
        let store = qless::datastore::default_store_path(run_dir, p);
        anyhow::ensure!(
            store.exists(),
            "no {} datastore at {} — run `qless extract` first",
            p.label(),
            store.display()
        );
        let idx = qless::datastore::reindex_store(&store, &opts)?;
        println!(
            "reindex: {} — {} rows → {} clusters × {} checkpoints (generation {:#x}) at {}",
            p.label(),
            idx.n_rows(),
            idx.n_clusters(),
            idx.n_checkpoints(),
            idx.generation(),
            qless::datastore::index_path(&store).display()
        );
    }
    Ok(())
}

fn score_select(cli: &Cli) -> Result<()> {
    let mut pipe = Pipeline::new(cli.config.clone())?;
    if cli.config.nprobe > 0 {
        return score_select_indexed(cli, &mut pipe);
    }
    if let Some((probe, rerank)) = cli.config.cascade_precisions()? {
        return score_select_cascade(cli, &mut pipe, probe, rerank);
    }
    let p = Precision::new(cli.config.bits, cli.config.scheme)?;
    let (ds, _) = pipe.build_datastore(p)?;
    // the run may have live (ingested) generations beyond the base build:
    // score whatever is actually there, composition included
    let live = pipe.open_live(p)?;
    let (all_scores, samples) = if live.generation() > 0 {
        println!(
            "live datastore: generation {} ({} rows, {} of them ingested)",
            live.generation(),
            live.n_rows(),
            live.n_rows() - ds.n_samples()
        );
        let samples = pipe.samples_with_extensions(&live)?;
        (pipe.influence_scores_all_live(&live)?, samples)
    } else {
        // one streamed datastore pass scores all benchmarks (--multi-scan)
        (pipe.influence_scores_all(&ds)?, pipe.corpus.samples.clone())
    };
    for bench in Benchmark::ALL {
        let scores = &all_scores[bench.name()];
        let sel = select_top_frac(scores, cli.config.select_frac);
        let dist = SourceDistribution::of(&samples, &sel);
        println!("{bench}: top {} — {}", sel.len(), dist.render());
        let top = &sel[..sel.len().min(3)];
        for &i in top {
            let s = &samples[i];
            println!("    [{:>7.4}] {} → {}", scores[i], s.prompt, s.answer);
        }
    }
    Ok(())
}

/// `qless score/select --nprobe P`: sub-linear selection through the
/// `.qidx` IVF sidecar — rank clusters by scoring their packed sign
/// centroids, scan only the top-`P` clusters per benchmark. Composes
/// with `--cascade` (the sidecar narrows the probe stage, the rerank
/// precision scores the survivors). A missing or rejected sidecar falls
/// back to the exact exhaustive path with a warning — never an error,
/// never a silently approximate answer from a corrupt grouping.
fn score_select_indexed(cli: &Cli, pipe: &mut Pipeline) -> Result<()> {
    let cfg = &cli.config;
    let run_dir = std::path::PathBuf::from(&cfg.run_dir);
    if let Some((probe, rerank)) = cfg.cascade_precisions()? {
        pipe.build_datastores(&[probe, rerank])?;
        let probe_live = pipe.open_live(probe)?;
        let store = qless::datastore::default_store_path(&run_dir, probe);
        let Some(idx) = qless::datastore::QuantIndex::open_for(&store, &probe_live) else {
            eprintln!(
                "warning: no usable index sidecar at {} — run `qless reindex`; \
                 falling back to the exhaustive cascade",
                qless::datastore::index_path(&store).display()
            );
            return score_select_cascade(cli, pipe, probe, rerank);
        };
        let n = probe_live.n_rows();
        let k_sel = (((n as f64) * cfg.select_frac).ceil() as usize).clamp(1, n);
        let rerank_live = pipe.open_live(rerank)?;
        let samples = pipe.samples_with_extensions(&rerank_live)?;
        let (tops, pass) = pipe.indexed_cascade_scores_all(
            probe,
            rerank,
            &idx,
            cfg.cascade_mult,
            k_sel,
            cfg.nprobe,
        )?;
        println!(
            "indexed cascade: {} clusters, nprobe {}, {} probe → {} rerank, {} live rows, {} read",
            idx.n_clusters(),
            cfg.nprobe.min(idx.n_clusters()),
            probe.label(),
            rerank.label(),
            n,
            human_bytes(pass.bytes_read)
        );
        render_top_selection(&tops, &samples);
        return Ok(());
    }
    let p = Precision::new(cfg.bits, cfg.scheme)?;
    pipe.build_datastore(p)?;
    let live = pipe.open_live(p)?;
    let store = qless::datastore::default_store_path(&run_dir, p);
    let Some(idx) = qless::datastore::QuantIndex::open_for(&store, &live) else {
        eprintln!(
            "warning: no usable index sidecar at {} — run `qless reindex`; \
             falling back to the exhaustive scan",
            qless::datastore::index_path(&store).display()
        );
        let mut plain = cli.clone();
        plain.config.nprobe = 0;
        return score_select(&plain);
    };
    let n = live.n_rows();
    let k_sel = (((n as f64) * cfg.select_frac).ceil() as usize).clamp(1, n);
    let samples = pipe.samples_with_extensions(&live)?;
    let (tops, pass, scanned) = pipe.indexed_scores_all(&live, &idx, cfg.nprobe, k_sel)?;
    println!(
        "indexed scan: {} clusters (stale rows {}), nprobe {}, {} of {} rows scanned, {} read",
        idx.n_clusters(),
        idx.stale_rows(),
        cfg.nprobe.min(idx.n_clusters()),
        scanned,
        n,
        human_bytes(pass.bytes_read)
    );
    render_top_selection(&tops, &samples);
    Ok(())
}

/// Shared renderer for top-list selections (cascade and indexed paths):
/// per-benchmark composition plus the three highest-scoring samples.
fn render_top_selection(
    tops: &std::collections::BTreeMap<&'static str, Vec<(usize, f32)>>,
    samples: &[qless::corpus::Sample],
) {
    for bench in Benchmark::ALL {
        let top = &tops[bench.name()];
        let sel: Vec<usize> = top.iter().map(|(i, _)| *i).collect();
        let dist = SourceDistribution::of(samples, &sel);
        println!("{bench}: top {} — {}", sel.len(), dist.render());
        for &(i, s) in top.iter().take(3) {
            let smp = &samples[i];
            println!("    [{s:>7.4}] {} → {}", smp.prompt, smp.answer);
        }
    }
}

/// `qless score/select --cascade PROBE,RERANK`: probe every live row at
/// the cheap precision, rerank only the top `--cascade-mult ×` selection
/// candidates at the expensive one, and select from the reranked list.
fn score_select_cascade(
    cli: &Cli,
    pipe: &mut Pipeline,
    probe: Precision,
    rerank: Precision,
) -> Result<()> {
    // the cascade reads two sibling stores; build any that are missing
    // (cached files are reused) in one extraction pass
    pipe.build_datastores(&[probe, rerank])?;
    let live = pipe.open_live(rerank)?;
    let n = live.n_rows();
    let k_sel = (((n as f64) * cli.config.select_frac).ceil() as usize).clamp(1, n);
    let samples = pipe.samples_with_extensions(&live)?;
    let (tops, pass) =
        pipe.cascade_scores_all(probe, rerank, cli.config.cascade_mult, k_sel)?;
    println!(
        "cascade: {} probe → {} rerank (mult {}), {} live rows, {} read",
        probe.label(),
        rerank.label(),
        cli.config.cascade_mult,
        n,
        human_bytes(pass.bytes_read)
    );
    render_top_selection(&tops, &samples);
    Ok(())
}

fn eval_baseline(cli: &Cli) -> Result<()> {
    let mut pipe = Pipeline::new(cli.config.clone())?;
    let base = pipe.base()?;
    let lora = qless::model::init_lora(&pipe.info, cli.config.seed);
    let scores = qless::eval::harness::evaluate(
        &pipe.rt,
        &pipe.info,
        &base,
        &lora,
        &pipe.world,
        cli.config.eval_per_task,
        cli.config.seed,
    )?;
    for (name, v) in &scores.scores {
        println!("{name}: {}", pct(*v));
    }
    println!("avg: {}", pct(scores.average()));
    Ok(())
}

/// Print greedy decodes of the pretrained base (+fresh LoRA) on a few
/// benchmark tasks — the fastest way to eyeball generation quality.
fn decode_demo(cli: &Cli) -> Result<()> {
    let mut pipe = Pipeline::new(cli.config.clone())?;
    let base = pipe.base()?;
    let lora = qless::model::init_lora(&pipe.info, cli.config.seed);
    let tok = qless::corpus::Tokenizer::default();
    let base_buf = pipe.rt.upload_f32(&base, &[pipe.info.d_base])?;
    for bench in Benchmark::ALL {
        let tasks = qless::eval::benchmarks::test_tasks(bench, &pipe.world, 4, cli.config.seed);
        let prompts: Vec<_> = tasks.iter().map(|t| t.sample.clone()).collect();
        let outs = qless::eval::decoder::greedy_decode(
            &pipe.rt, &pipe.info, &base_buf, &lora, &prompts, &tok, 24,
        )?;
        println!("--- {bench} ---");
        for (t, o) in tasks.iter().zip(&outs) {
            println!("  prompt: {}", t.sample.prompt);
            println!("  gold:   {:?}   decoded: {:?}", t.sample.answer, o);
        }
    }
    Ok(())
}

fn run_pipeline(cli: &Cli) -> Result<()> {
    let mut pipe = Pipeline::new(cli.config.clone())?;
    let p = Precision::new(cli.config.bits, cli.config.scheme)?;
    let r = pipe.run_method(Method::Qless(p))?;
    let mut t = Table::new(
        &format!("pipeline result — {}", r.label),
        &["benchmark", "score", "selection composition"],
    );
    for bench in Benchmark::ALL {
        t.row(vec![
            bench.name().to_string(),
            pct(r.scores[bench.name()]),
            r.distributions[bench.name()].render(),
        ]);
    }
    print!("{}", t.render());
    println!("average: {}   datastore: {}", pct(r.average), human_bytes(r.storage_bytes));
    Ok(())
}
