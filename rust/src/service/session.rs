//! The resident influence session: one datastore opened (and validated)
//! once, per-checkpoint η weights read once, recently-scanned shards
//! pinned in a byte-budgeted LRU cache so repeat scans hit RAM instead of
//! disk, and a score cache keyed by (task digest, datastore generation) so
//! identical queries never rescan at all.
//!
//! [`Session::answer_batch`] is the serving hot path: resolve score-cache
//! hits, deduplicate identical queries within the batch, then run **one**
//! fused [`MultiScan`] pass over the store for every distinct uncached
//! task. Shards come from the cache when pinned and from
//! `ShardReader::seek_to_row` random-access reads when not; either way the
//! scoring kernels see the same [`crate::datastore::RowsView`] bytes, so
//! served scores are bit-identical to the one-shot `--multi-scan` pipeline
//! (`influence::score_datastore_tasks`), which the e2e suite asserts.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::datastore::{Datastore, Header, OwnedShard};
use crate::grads::FeatureMatrix;
use crate::influence::{MultiScan, ScanStats};
use crate::info;

use super::cache::{fnv1a, task_digest, LruCache, FNV_OFFSET};

/// Knobs of a resident session (a subset of `ServeOpts`, usable without
/// the TCP front end — tests and the in-process path build these directly).
#[derive(Debug, Clone, Copy)]
pub struct SessionOpts {
    /// Fixed rows per shard; 0 = derive from `mem_budget_mb`.
    pub shard_rows: usize,
    /// Shard-cache byte budget in MiB; also bounds the scan's streaming
    /// shard size (the same contract as the batch pipeline's
    /// `--mem-budget-mb`, so peak residency is ≈ 2× this: one streaming
    /// buffer + the pinned cache).
    pub mem_budget_mb: usize,
    /// Score-cache capacity in entries (each entry is one `n`-float score
    /// vector); 0 disables score caching.
    pub score_cache_entries: usize,
}

impl Default for SessionOpts {
    fn default() -> SessionOpts {
        SessionOpts {
            shard_rows: 0,
            mem_budget_mb: crate::config::DEFAULT_MEM_BUDGET_MB,
            score_cache_entries: 64,
        }
    }
}

/// Cumulative accounting of a session — the payload of the wire `stats`
/// op. Cache-efficacy counters are the interesting part: a warm repeat
/// query moves `score_cache_hits` (or `shard_cache_hits`) without moving
/// `disk_shard_reads`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServiceStats {
    /// Score queries answered (including cache hits).
    pub queries: u64,
    /// `answer_batch` calls (micro-batches admitted).
    pub batches: u64,
    /// Fused datastore passes executed (≤ batches; 0-miss batches skip it).
    pub fused_passes: u64,
    /// Queries answered from the score cache without any scan.
    pub score_cache_hits: u64,
    /// Shards served from the RAM cache during scans.
    pub shard_cache_hits: u64,
    /// Shards read from the datastore file (cold misses).
    pub disk_shard_reads: u64,
    /// Bytes currently pinned by the shard cache.
    pub shard_cache_bytes: u64,
    /// Rows scored across all fused passes.
    pub rows_scored: u64,
}

/// One influence query: raw (unquantized) validation gradient features per
/// warmup checkpoint, in checkpoint order — exactly the per-task shape
/// [`crate::influence::score_datastore_tasks`] takes.
#[derive(Debug, Clone)]
pub struct ScoreQuery {
    /// One feature matrix per checkpoint (`val[ci]` is `n_val × k`).
    pub val: Vec<FeatureMatrix>,
}

impl ScoreQuery {
    /// The score-cache key for this query's features (see
    /// [`task_digest`]).
    pub fn digest(&self) -> u64 {
        task_digest(&self.val)
    }

    /// Cheap admission-time validation against the served store's
    /// geometry: checkpoint count, feature dimension, non-empty matrices,
    /// flat-data length, finiteness. Runs before the query is enqueued so
    /// one malformed query gets its own error response instead of failing
    /// a whole batch.
    pub fn validate(&self, header: &Header) -> Result<()> {
        let c = header.n_checkpoints as usize;
        anyhow::ensure!(
            self.val.len() == c,
            "query has {} checkpoint feature sets, datastore has {c}",
            self.val.len()
        );
        for (ci, m) in self.val.iter().enumerate() {
            anyhow::ensure!(
                m.k == header.k as usize,
                "checkpoint {ci}: feature dim {} != datastore k {}",
                m.k,
                header.k
            );
            anyhow::ensure!(m.n > 0, "checkpoint {ci}: empty validation features");
            // checked: n and k come off the wire, and an n·k that wraps in
            // release builds could pass an unchecked equality against a
            // tiny data length and then drive an n-sized allocation
            let expect = m.n.checked_mul(m.k);
            anyhow::ensure!(
                expect == Some(m.data.len()),
                "checkpoint {ci}: {} values for {}×{} features",
                m.data.len(),
                m.n,
                m.k
            );
            if let Some(j) = m.data.iter().position(|x| !x.is_finite()) {
                bail!("checkpoint {ci}: non-finite validation feature {} at index {j}", m.data[j]);
            }
        }
        Ok(())
    }
}

/// One answered query: the full per-sample score vector (shared, so cache
/// hits are pointer clones) plus provenance — whether it came from the
/// score cache and, if not, the fused pass that produced it.
#[derive(Debug, Clone)]
pub struct Answer {
    /// Influence score of every training sample, in sample order.
    pub scores: Arc<Vec<f32>>,
    /// True when served from the score cache without any scan.
    pub cached: bool,
    /// Distinct tasks fused into the producing pass (0 on a cache hit).
    pub batched: usize,
    /// I/O accounting of the producing pass (zeroed on a cache hit). All
    /// answers of one micro-batch share the same pass, which is how the
    /// e2e test asserts a burst of Q queries cost one datastore traversal.
    pub pass: ScanStats,
}

/// A warm, long-lived handle over one datastore (see the module docs).
pub struct Session {
    ds: Datastore,
    generation: u64,
    etas: Vec<f32>,
    rows_per_shard: usize,
    shard_cache: LruCache<(usize, usize), Arc<OwnedShard>>,
    score_cache: LruCache<u64, Arc<Vec<f32>>>,
    stats: ServiceStats,
}

impl Session {
    /// Open and validate the datastore at `path`, read every checkpoint's
    /// η once, and size the caches from `opts`. After this, a fully-warm
    /// query touches no file I/O at all.
    pub fn open(path: &Path, opts: SessionOpts) -> Result<Session> {
        let ds = Datastore::open(path)
            .with_context(|| format!("opening served datastore {path:?}"))?;
        let generation = generation_of(path, &ds.header);
        let mut etas = Vec::with_capacity(ds.n_checkpoints());
        for ci in 0..ds.n_checkpoints() {
            etas.push(ds.shard_reader(ci, 1)?.eta());
        }
        let rows_per_shard = ds.rows_per_shard(opts.shard_rows, opts.mem_budget_mb.max(1));
        let cache_budget = opts.mem_budget_mb.max(1) << 20;
        info!(
            "session: {} samples × k={} × {} checkpoints at {} (gen {generation:#x}, \
             {rows_per_shard} rows/shard, {} MiB shard cache, {} score-cache entries)",
            ds.n_samples(),
            ds.header.k,
            ds.n_checkpoints(),
            ds.header.precision.label(),
            opts.mem_budget_mb.max(1),
            opts.score_cache_entries,
        );
        Ok(Session {
            ds,
            generation,
            etas,
            rows_per_shard,
            shard_cache: LruCache::new(cache_budget),
            score_cache: LruCache::new(opts.score_cache_entries),
            stats: ServiceStats::default(),
        })
    }

    /// The served store's header (geometry + precision).
    pub fn header(&self) -> &Header {
        &self.ds.header
    }

    /// The datastore generation: a digest of the header, file size and
    /// mtime captured at open. Score-cache entries are implicitly keyed by
    /// it (the cache lives inside the session, which is pinned to one
    /// generation), and responses echo it so clients can detect a restart
    /// over a rebuilt store.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Rows per streamed/cached shard, resolved from the session's opts.
    pub fn rows_per_shard(&self) -> usize {
        self.rows_per_shard
    }

    /// Cumulative session accounting (the `stats` op's payload).
    pub fn stats(&self) -> ServiceStats {
        let mut s = self.stats;
        s.shard_cache_bytes = self.shard_cache.weight() as u64;
        s
    }

    /// Answer one micro-batch of (already validated) queries: score-cache
    /// hits are answered instantly, identical queries within the batch are
    /// deduplicated, and every remaining distinct task rides **one** fused
    /// pass over the store. Returns one [`Answer`] per query, in order.
    pub fn answer_batch(&mut self, queries: &[ScoreQuery]) -> Result<Vec<Answer>> {
        self.stats.batches += 1;
        self.stats.queries += queries.len() as u64;
        let digests: Vec<u64> = queries.iter().map(|q| q.digest()).collect();
        let mut answers: Vec<Option<Answer>> = vec![None; queries.len()];
        // distinct uncached digests, in arrival order (batch sizes are
        // small — max_batch_tasks — so linear dedup beats a map here)
        let mut misses: Vec<u64> = Vec::new();
        for (i, d) in digests.iter().enumerate() {
            if let Some(scores) = self.score_cache.get(d) {
                self.stats.score_cache_hits += 1;
                answers[i] = Some(Answer {
                    scores,
                    cached: true,
                    batched: 0,
                    pass: ScanStats::default(),
                });
            } else if !misses.contains(d) {
                misses.push(*d);
            }
        }
        if !misses.is_empty() {
            let reps: Vec<&ScoreQuery> = misses
                .iter()
                .map(|d| {
                    let i = digests.iter().position(|x| x == d).expect("digest from this batch");
                    &queries[i]
                })
                .collect();
            let tasks: Vec<&[FeatureMatrix]> = reps.iter().map(|q| q.val.as_slice()).collect();
            let (totals, pass) = self.scan_fused(&tasks)?;
            let shared: Vec<Arc<Vec<f32>>> = totals.into_iter().map(Arc::new).collect();
            for (d, scores) in misses.iter().zip(&shared) {
                self.score_cache.insert(*d, Arc::clone(scores), 1);
            }
            for (i, d) in digests.iter().enumerate() {
                if answers[i].is_none() {
                    let t = misses.iter().position(|x| x == d).expect("miss was collected");
                    answers[i] = Some(Answer {
                        scores: Arc::clone(&shared[t]),
                        cached: false,
                        batched: misses.len(),
                        pass,
                    });
                }
            }
        }
        Ok(answers.into_iter().map(|a| a.expect("every query answered")).collect())
    }

    /// One fused multi-task pass over the store, preferring pinned shards:
    /// cache hits feed the scan straight from RAM; misses are read with a
    /// seek-based [`crate::datastore::ShardReader`], fed, and pinned for
    /// the next pass (LRU-evicted under the byte budget).
    fn scan_fused(&mut self, tasks: &[&[FeatureMatrix]]) -> Result<(Vec<Vec<f32>>, ScanStats)> {
        let mut scan = MultiScan::try_new(&self.ds.header, tasks)?;
        let n = self.ds.n_samples();
        let n_shards = n.div_ceil(self.rows_per_shard).max(1);
        for ci in 0..self.ds.n_checkpoints() {
            let eta = self.etas[ci];
            let mut reader = None;
            for si in 0..n_shards {
                let key = (ci, si);
                if let Some(shard) = self.shard_cache.get(&key) {
                    self.stats.shard_cache_hits += 1;
                    scan.feed(ci, eta, shard.start, &shard.rows());
                    continue;
                }
                if reader.is_none() {
                    reader = Some(self.ds.shard_reader(ci, self.rows_per_shard)?);
                }
                let r = reader.as_mut().expect("reader just opened");
                r.seek_to_row(si * self.rows_per_shard);
                let shard = r
                    .next_shard()?
                    .with_context(|| format!("shard {si} of checkpoint {ci} out of range"))?;
                let owned = Arc::new(shard.to_owned_shard());
                self.stats.disk_shard_reads += 1;
                scan.feed(ci, eta, owned.start, &owned.rows());
                let weight = owned.byte_weight();
                self.shard_cache.insert(key, owned, weight);
            }
        }
        self.stats.fused_passes += 1;
        let (totals, pass) = scan.finish();
        self.stats.rows_scored += pass.rows_read;
        Ok((totals, pass))
    }
}

/// Digest identifying one on-disk datastore build: header bytes + file
/// size + mtime (when available). See [`Session::generation`].
fn generation_of(path: &Path, header: &Header) -> u64 {
    let mut h = fnv1a(FNV_OFFSET, &header.encode());
    if let Ok(meta) = std::fs::metadata(path) {
        h = fnv1a(h, &meta.len().to_le_bytes());
        if let Ok(mtime) = meta.modified() {
            if let Ok(d) = mtime.duration_since(std::time::UNIX_EPOCH) {
                h = fnv1a(h, &d.as_nanos().to_le_bytes());
            }
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::influence::{score_datastore_tasks, ScoreOpts};
    use crate::quant::{Precision, Scheme};
    use crate::util::prop::{normal_features as feats, seeded_datastore};
    use std::path::PathBuf;

    fn build_store(bits: u8, n: usize, k: usize, etas: &[f32], tag: &str) -> PathBuf {
        let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
        let p = Precision::new(bits, scheme).unwrap();
        let path = std::env::temp_dir().join(format!(
            "qless_sess_{tag}_{bits}_{}_{:?}.qlds",
            std::process::id(),
            std::thread::current().id()
        ));
        seeded_datastore(&path, p, n, k, etas, 0);
        path
    }

    fn task(k: usize, seed: u64, ckpts: usize) -> Vec<FeatureMatrix> {
        (0..ckpts).map(|ci| feats(3, k, seed + ci as u64)).collect()
    }

    #[test]
    fn session_scores_match_batch_pipeline_exactly() {
        let (n, k) = (23usize, 64usize);
        let path = build_store(4, n, k, &[0.7, 0.3], "exact");
        let ds = Datastore::open(&path).unwrap();
        let t0 = task(k, 100, 2);
        let t1 = task(k, 200, 2);
        let (want, _) = score_datastore_tasks(
            &ds,
            &[&t0, &t1],
            ScoreOpts { shard_rows: 5, ..Default::default() },
            None,
        )
        .unwrap();
        let opts = SessionOpts { shard_rows: 5, mem_budget_mb: 4, score_cache_entries: 8 };
        let mut sess = Session::open(&path, opts).unwrap();
        assert_eq!(sess.rows_per_shard(), 5);
        let queries = vec![ScoreQuery { val: t0.clone() }, ScoreQuery { val: t1.clone() }];
        for q in &queries {
            q.validate(sess.header()).unwrap();
        }
        let answers = sess.answer_batch(&queries).unwrap();
        assert_eq!(answers.len(), 2);
        for (t, a) in answers.iter().enumerate() {
            assert!(!a.cached);
            assert_eq!(a.batched, 2, "both tasks fused into one pass");
            assert_eq!(a.pass.tasks, 2);
            assert_eq!(*a.scores, want[t], "task {t}: served vs pipeline scores");
        }
        // both answers share one pass: shard traffic of a single scan
        assert_eq!(answers[0].pass, answers[1].pass);
        assert_eq!(answers[0].pass.shards_read, 2 * n.div_ceil(5));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn warm_queries_skip_disk_and_identical_queries_skip_scans() {
        let (n, k) = (16usize, 64usize);
        let path = build_store(8, n, k, &[1.0], "warm");
        let opts = SessionOpts { shard_rows: 4, mem_budget_mb: 16, score_cache_entries: 4 };
        let mut sess = Session::open(&path, opts).unwrap();
        let q0 = ScoreQuery { val: task(k, 300, 1) };
        let a0 = sess.answer_batch(std::slice::from_ref(&q0)).unwrap();
        let cold = sess.stats();
        assert_eq!(cold.disk_shard_reads, 4, "cold pass reads every shard");
        assert_eq!(cold.fused_passes, 1);
        // identical query: score cache answers without any scan
        let a1 = sess.answer_batch(std::slice::from_ref(&q0)).unwrap();
        assert!(a1[0].cached);
        assert_eq!(a1[0].scores, a0[0].scores);
        let s1 = sess.stats();
        assert_eq!(s1.score_cache_hits, 1);
        assert_eq!(s1.fused_passes, 1, "no new pass");
        assert_eq!(s1.disk_shard_reads, cold.disk_shard_reads);
        // different task, warm shard cache: a scan, but zero disk reads
        let q1 = ScoreQuery { val: task(k, 301, 1) };
        let a2 = sess.answer_batch(std::slice::from_ref(&q1)).unwrap();
        assert!(!a2[0].cached);
        let s2 = sess.stats();
        assert_eq!(s2.fused_passes, 2);
        assert_eq!(s2.disk_shard_reads, cold.disk_shard_reads, "warm scan is RAM-only");
        assert_eq!(s2.shard_cache_hits, 4);
        assert!(s2.shard_cache_bytes > 0);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn batch_dedup_fuses_identical_queries_into_one_task() {
        let (n, k) = (12usize, 64usize);
        let path = build_store(2, n, k, &[0.5], "dedup");
        let mut sess = Session::open(
            &path,
            SessionOpts { shard_rows: 0, mem_budget_mb: 8, score_cache_entries: 0 },
        )
        .unwrap();
        let a = ScoreQuery { val: task(k, 400, 1) };
        let b = ScoreQuery { val: task(k, 401, 1) };
        let batch = vec![a.clone(), b.clone(), a.clone(), a.clone()];
        let answers = sess.answer_batch(&batch).unwrap();
        for ans in &answers {
            assert_eq!(ans.batched, 2, "4 queries, 2 distinct tasks");
            assert_eq!(ans.pass.tasks, 2);
        }
        assert_eq!(answers[0].scores, answers[2].scores);
        assert_eq!(answers[0].scores, answers[3].scores);
        assert_ne!(answers[0].scores, answers[1].scores);
        // score cache disabled: the same batch rescans, same results
        let again = sess.answer_batch(&batch).unwrap();
        assert_eq!(again[0].scores, answers[0].scores);
        assert!(!again[0].cached);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn validate_rejects_malformed_queries() {
        let (n, k) = (8usize, 64usize);
        let path = build_store(8, n, k, &[1.0, 1.0], "val");
        let sess = Session::open(&path, SessionOpts::default()).unwrap();
        let h = *sess.header();
        // wrong checkpoint count
        assert!(ScoreQuery { val: task(k, 1, 1) }.validate(&h).is_err());
        // wrong k
        assert!(ScoreQuery { val: task(32, 1, 2) }.validate(&h).is_err());
        // empty matrix
        let empty = vec![
            FeatureMatrix { n: 0, k, data: vec![] },
            FeatureMatrix { n: 0, k, data: vec![] },
        ];
        assert!(ScoreQuery { val: empty }.validate(&h).is_err());
        // flat-length mismatch
        let mut bad = task(k, 1, 2);
        bad[0].data.pop();
        assert!(ScoreQuery { val: bad }.validate(&h).is_err());
        // n·k that wraps to 0 in release builds: checked_mul must reject,
        // or a hostile wire request drives an n-sized allocation
        let huge = vec![
            FeatureMatrix { n: usize::MAX / 2 + 1, k, data: vec![] },
            FeatureMatrix { n: usize::MAX / 2 + 1, k, data: vec![] },
        ];
        assert!(ScoreQuery { val: huge }.validate(&h).is_err());
        // non-finite
        let mut nan = task(k, 1, 2);
        nan[1].data[5] = f32::NAN;
        let err = ScoreQuery { val: nan }.validate(&h).unwrap_err();
        assert!(format!("{err:#}").contains("non-finite"), "{err:#}");
        // a good one passes
        ScoreQuery { val: task(k, 1, 2) }.validate(&h).unwrap();
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn generation_distinguishes_rebuilt_stores() {
        let path = build_store(8, 8, 64, &[1.0], "gen1");
        let s1 = Session::open(&path, SessionOpts::default()).unwrap();
        let g1 = s1.generation();
        drop(s1);
        let path2 = build_store(8, 9, 64, &[1.0], "gen2");
        let s2 = Session::open(&path2, SessionOpts::default()).unwrap();
        assert_ne!(g1, s2.generation(), "different geometry, different generation");
        std::fs::remove_file(path).ok();
        std::fs::remove_file(path2).ok();
    }
}
