//! Scatter-gather serving throughput and failure-recovery latency: one
//! coordinator over N local workers vs the resident single-node server,
//! over real sockets — the numbers recorded in EXPERIMENTS.md §Perf.
//!
//! Three sections:
//!
//! * **1 vs N workers** — queries/sec and cold/warm latency at Q
//!   concurrent clients for a single-node `Server` and a coordinator at
//!   1/2/4 workers. The coordinator pays a per-query fleet probe plus a
//!   fan-out hop, so at small stores it *loses* to single-node; the win
//!   is each worker scanning 1/N of the rows (and in a real deployment,
//!   1/N of the store resident per machine).
//! * **cold vs warm** — the first round pays disk on every worker; warm
//!   rounds scan each worker's pinned shard-cache slice.
//! * **worker-kill recovery** — kill one of three workers mid-stream and
//!   measure the first-query latency while the fleet heals (probe
//!   failure → exclusion → 2-way repartition) and the steady state after.
//!
//! Score caches are disabled and every (client, round) uses distinct
//! validation features, so every query pays a real scan.

use std::sync::{Arc, Barrier};
use std::time::Instant;

use qless::datastore::DatastoreWriter;
use qless::grads::FeatureMatrix;
use qless::quant::{Precision, Scheme};
use qless::service::{Client, Coordinator, CoordinatorOpts, ServeOpts, Server};
use qless::util::json::Json;
use qless::util::stats::fmt_secs;
use qless::util::Rng;

fn feats(n: usize, k: usize, seed: u64) -> FeatureMatrix {
    let mut rng = Rng::new(seed);
    FeatureMatrix { n, k, data: (0..n * k).map(|_| rng.normal() as f32).collect() }
}

fn build(n: usize, k: usize) -> std::path::PathBuf {
    let p = Precision::new(4, Scheme::Absmax).unwrap();
    let path = std::env::temp_dir()
        .join(format!("qless_bench_scatter_{}.qlds", std::process::id()));
    let f = feats(n, k, 7);
    let mut w = DatastoreWriter::create(&path, p, n, k, 1).unwrap();
    w.begin_checkpoint(1.0).unwrap();
    for i in 0..n {
        w.append_features(f.row(i)).unwrap();
    }
    w.end_checkpoint().unwrap();
    w.finalize().unwrap();
    path
}

fn worker_opts(q: usize) -> ServeOpts {
    ServeOpts {
        addr: "127.0.0.1:0".into(),
        batch_window_ms: 0,
        max_batch_tasks: 32,
        shard_rows: 0,
        mem_budget_mb: 64,
        score_cache_entries: 0,
        workers: q + 2,
        queue_cap: 256,
    }
}

/// Drive Q concurrent clients × `rounds` distinct queries against `addr`;
/// returns per-query `(latency_s, is_first_round)`.
fn drive(addr: std::net::SocketAddr, q: usize, rounds: usize, k: usize, nv: usize, seed: usize) -> Vec<(f64, bool)> {
    let barrier = Arc::new(Barrier::new(q));
    let handles: Vec<_> = (0..q)
        .map(|ci| {
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let mut client = Client::connect(addr).unwrap();
                let mut lat = Vec::with_capacity(rounds);
                barrier.wait();
                for r in 0..rounds {
                    let val = vec![feats(nv, k, (seed + ci * 1000 + r) as u64)];
                    let t = Instant::now();
                    client.score(&val, 10, false).unwrap();
                    lat.push((t.elapsed().as_secs_f64(), r == 0));
                }
                lat
            })
        })
        .collect();
    handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
}

/// Print one section line and return its machine-readable twin for
/// `reports/bench_serve.json` — latency quantiles in seconds plus the
/// derived throughputs (queries/s, and rows/s = queries/s × rows each
/// query scans) so future PRs have a perf trajectory to diff against.
fn report(label: &str, all: &[(f64, bool)], wall: f64, rows_per_query: usize) -> Json {
    let cold: Vec<f64> = all.iter().filter(|(_, c)| *c).map(|(s, _)| *s).collect();
    let mut warm: Vec<f64> = all.iter().filter(|(_, c)| !*c).map(|(s, _)| *s).collect();
    warm.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| warm[((p * (warm.len() - 1) as f64).round() as usize).min(warm.len() - 1)];
    let cold_mean = cold.iter().sum::<f64>() / cold.len().max(1) as f64;
    let qps = all.len() as f64 / wall;
    println!(
        "{label}: {:>7.1} q/s  cold {:>9}  warm p50 {:>9}  p99 {:>9}",
        qps,
        fmt_secs(cold_mean),
        fmt_secs(pct(0.50)),
        fmt_secs(pct(0.99)),
    );
    let mut j = Json::obj();
    j.set("section", label.trim())
        .set("queries", all.len())
        .set("queries_per_s", qps)
        .set("rows_per_s", qps * rows_per_query as f64)
        .set("cold_mean_s", cold_mean)
        .set("warm_p50_s", pct(0.50))
        .set("warm_p95_s", pct(0.95))
        .set("warm_p99_s", pct(0.99));
    j
}

fn main() {
    let (n, k, nv) = (8192usize, 512usize, 8usize);
    let (q, rounds) = (4usize, 6usize);
    let path = build(n, k);
    println!("== bench_serve_distributed: {n}×{k} 4-bit store, Q={q} clients × {rounds} rounds ==");
    let mut sections: Vec<Json> = Vec::new();

    // single-node baseline
    {
        let server = Server::start(&path, worker_opts(q)).unwrap();
        let t = Instant::now();
        let all = drive(server.addr(), q, rounds, k, nv, 10_000);
        sections.push(report("single-node      ", &all, t.elapsed().as_secs_f64(), n));
        server.stop();
        server.join().unwrap();
    }

    // coordinator at 1 / 2 / 4 workers — same protocol, same answers
    for workers in [1usize, 2, 4] {
        let co = Coordinator::start_local(
            &path,
            workers,
            worker_opts(q),
            CoordinatorOpts { addr: "127.0.0.1:0".into(), ..Default::default() },
        )
        .unwrap();
        let t = Instant::now();
        let all = drive(co.addr(), q, rounds, k, nv, 20_000 + workers * 100);
        sections.push(report(
            &format!("scatter {workers} worker(s)"),
            &all,
            t.elapsed().as_secs_f64(),
            n,
        ));
        co.stop();
        co.join().unwrap();
    }

    // worker-kill recovery: 3 workers, warm the fleet, kill one, measure
    // the first post-kill query (detection + 2-way repartition) and the
    // healed steady state
    {
        let co = Coordinator::start_local(
            &path,
            3,
            worker_opts(q),
            CoordinatorOpts { addr: "127.0.0.1:0".into(), ..Default::default() },
        )
        .unwrap();
        let mut c = Client::connect(co.addr()).unwrap();
        for r in 0..3 {
            let val = vec![feats(nv, k, 30_000 + r)];
            c.score(&val, 10, false).unwrap();
        }
        co.local_workers()[1].stop();
        let val = vec![feats(nv, k, 31_000)];
        let t = Instant::now();
        c.score(&val, 10, false).unwrap();
        let recovery = t.elapsed().as_secs_f64();
        let mut healed = Vec::new();
        for r in 0..5 {
            let val = vec![feats(nv, k, 32_000 + r)];
            let t = Instant::now();
            c.score(&val, 10, false).unwrap();
            healed.push(t.elapsed().as_secs_f64());
        }
        healed.sort_by(|a, b| a.partial_cmp(b).unwrap());
        println!(
            "worker-kill (3→2): first query {:>9}  healed p50 {:>9}",
            fmt_secs(recovery),
            fmt_secs(healed[healed.len() / 2]),
        );
        let mut j = Json::obj();
        j.set("section", "worker-kill 3->2")
            .set("recovery_first_query_s", recovery)
            .set("healed_p50_s", healed[healed.len() / 2]);
        sections.push(j);
        c.shutdown().unwrap();
        co.join().unwrap();
    }

    // machine-readable twin of the lines above, diffed across PRs
    let mut out = Json::obj();
    out.set("bench", "bench_serve_distributed")
        .set("n_rows", n)
        .set("k", k)
        .set("clients", q)
        .set("rounds", rounds)
        .set("sections", sections);
    std::fs::create_dir_all("reports").unwrap();
    std::fs::write("reports/bench_serve.json", out.encode_pretty()).unwrap();
    println!("wrote reports/bench_serve.json");
    std::fs::remove_file(path).ok();
}
