//! Influence-scoring throughput: the scoring paths over the same
//! datastore — the dequantize-to-f32 reference, the integer-domain engine
//! (2/4/8-bit), the packed 1-bit XNOR+popcount kernel, the XLA Pallas
//! tile, and the batched multi-query scan. This is the §Perf centerpiece:
//! every sub-16-bit path must beat the f32 reference because it touches a
//! fraction of the memory and does integer math in the hot loop, and Q
//! validation tasks must cost ~one single-task pass, not Q. The cascade
//! rows sweep the §10 candidate multiplier (1-bit probe → 8-bit rerank)
//! and print bytes-read reduction + recall@k against the exhaustive scan.
//!
//! The kernel-variant section sweeps every dispatchable kernel (scalar
//! reference, blocked, AVX2/NEON) over the fused Q=4 scan per bitwidth
//! and writes the machine-readable twin `reports/bench_influence.json`
//! (rows/s, bytes/s and speedup-vs-scalar per bitwidth × variant — the
//! EXPERIMENTS.md §Perf iteration 12 numbers, diffable across PRs).
//!
//! The final section load-tests the resident query service (`qless
//! serve`) over real sockets: queries/sec and cold/warm latency
//! percentiles vs the micro-batch window at Q ∈ {1, 4, 16} concurrent
//! clients — the numbers recorded in EXPERIMENTS.md §Perf iteration 7.

use std::path::PathBuf;

use qless::datastore::{Datastore, DatastoreWriter};
use qless::grads::FeatureMatrix;
use qless::influence::native::{
    scores_1bit, scores_dense, scores_int_rows, scores_rows_with, ValFeatures,
};
use qless::influence::{score_datastore, score_datastore_tasks, ScoreOpts};
use qless::quant::{Precision, Scheme};
use qless::util::cpu::{self, Kernel};
use qless::util::json::Json;
use qless::util::stats::bench;
use qless::util::table::human_bytes;
use qless::util::Rng;

fn feats(n: usize, k: usize, seed: u64) -> FeatureMatrix {
    let mut rng = Rng::new(seed);
    FeatureMatrix { n, k, data: (0..n * k).map(|_| rng.normal() as f32).collect() }
}

fn build(bits: u8, n: usize, k: usize) -> (Datastore, PathBuf) {
    let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
    let p = Precision::new(bits, scheme).unwrap();
    let path = std::env::temp_dir().join(format!("qless_bench_inf_{bits}_{}.qlds", std::process::id()));
    let f = feats(n, k, 7);
    let mut w = DatastoreWriter::create(&path, p, n, k, 1).unwrap();
    w.begin_checkpoint(1.0).unwrap();
    for i in 0..n {
        w.append_features(f.row(i)).unwrap();
    }
    w.end_checkpoint().unwrap();
    w.finalize().unwrap();
    (Datastore::open(&path).unwrap(), path)
}

fn main() {
    let (n, k, nv) = (4096usize, 512usize, 32usize);
    let pairs = (n * nv) as f64;
    let vraw = feats(nv, k, 9);
    println!("== bench_influence: {n} train × {nv} val × k={k} (one checkpoint) ==");

    let mut paths = Vec::new();
    for bits in [16u8, 8, 4, 2, 1] {
        let (ds, path) = build(bits, n, k);
        paths.push(path);
        let block = ds.load_checkpoint(0).unwrap();
        let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
        let val = ValFeatures::prepare(&vraw, Precision::new(bits, scheme).unwrap());
        let r = bench(&format!("dense_{bits}bit (f32 reference)"), pairs, "pair", || {
            std::hint::black_box(scores_dense(&block, &val));
        });
        println!("{}", r.report_line());
        if matches!(bits, 2 | 4 | 8) {
            // the integer-domain engine: same scores (±1e-5), stored-code
            // dot + zero-point fixup, no dequantize/normalize in the loop
            let r = bench(&format!("int_{bits}bit"), pairs, "pair", || {
                std::hint::black_box(scores_int_rows(&block.rows(), &val));
            });
            println!("{}", r.report_line());
        }
        if bits == 1 {
            let r = bench("popcount_1bit", pairs, "pair", || {
                std::hint::black_box(scores_1bit(&block, &val));
            });
            println!("{}", r.report_line());
        }

        // streamed scan: same scores, O(shard) resident instead of O(block)
        let rows_per_shard = ds.rows_per_shard(0, 1); // 1 MiB budget
        let resident = rows_per_shard as u64 * ds.header.resident_row_bytes();
        let r = bench(
            &format!(
                "streamed_{bits}bit ({} resident vs {} block)",
                human_bytes(resident),
                human_bytes(ds.header.block_bytes()),
            ),
            pairs,
            "pair",
            || {
                std::hint::black_box(
                    score_datastore(
                        &ds,
                        std::slice::from_ref(&vraw),
                        ScoreOpts { mem_budget_mb: 1, ..Default::default() },
                        None,
                    )
                    .unwrap(),
                );
            },
        );
        println!("{}", r.report_line());
    }

    // kernel variants (PR 9): the fused Q=4 scan per bitwidth × every
    // variant this machine supports — scalar is the pinned autovectorized
    // baseline, `blocked` isolates the rows×tasks tiling, avx2/neon add
    // intrinsics on top. rows/s and bytes/s per cell land in
    // reports/bench_influence.json so the perf trajectory is diffable
    // across PRs; the headline ratio is 8-bit fused dispatch vs scalar.
    {
        let q = 4usize;
        let nv_task = nv / q; // 8 val rows per task, Q·nv_task = nv total
        let variants = cpu::available();
        println!(
            "-- kernel variants (Q={q} fused, {} val rows/task; active: {}) --",
            nv_task,
            cpu::active().label()
        );
        let mut sections: Vec<Json> = Vec::new();
        let mut speedup_8bit = 0f64;
        for bits in [1u8, 2, 4, 8] {
            let (ds, path) = build(bits, n, k);
            let block = ds.load_checkpoint(0).unwrap();
            let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
            let p = Precision::new(bits, scheme).unwrap();
            let tasks_raw: Vec<FeatureMatrix> =
                (0..q).map(|t| feats(nv_task, k, 60 + t as u64)).collect();
            let refs: Vec<&FeatureMatrix> = tasks_raw.iter().collect();
            let val = ValFeatures::try_prepare_tasks(&refs, p).unwrap();
            let row_bytes = ds.header.resident_row_bytes() as f64;
            let mut scalar_rows_s = 0f64;
            for &kernel in &variants {
                let rows = block.rows();
                let r = bench(
                    &format!("kernel_{bits}bit_{}", kernel.label()),
                    n as f64,
                    "row",
                    || {
                        std::hint::black_box(scores_rows_with(&rows, &val, kernel));
                    },
                );
                let rows_s = r.throughput();
                if kernel == Kernel::Scalar {
                    scalar_rows_s = rows_s;
                }
                let ratio = if scalar_rows_s > 0.0 { rows_s / scalar_rows_s } else { 1.0 };
                if bits == 8 && kernel == cpu::active() {
                    speedup_8bit = ratio;
                }
                println!(
                    "{}  [{}/s scanned, {:.2}x vs scalar]",
                    r.report_line(),
                    human_bytes((rows_s * row_bytes) as u64),
                    ratio,
                );
                let mut j = Json::obj();
                j.set("section", "kernel_variant")
                    .set("bits", bits as usize)
                    .set("variant", kernel.label())
                    .set("q_tasks", q)
                    .set("rows_per_s", rows_s)
                    .set("bytes_per_s", rows_s * row_bytes)
                    .set("speedup_vs_scalar", ratio);
                sections.push(j);
            }
            std::fs::remove_file(path).ok();
        }
        let mut out = Json::obj();
        out.set("bench", "bench_influence")
            .set("n_rows", n)
            .set("k", k)
            .set("q_tasks", q)
            .set("val_rows_per_task", nv_task)
            .set("active_kernel", cpu::active().label())
            .set("fused_8bit_speedup_vs_scalar", speedup_8bit)
            .set("sections", sections);
        std::fs::create_dir_all("reports").unwrap();
        std::fs::write("reports/bench_influence.json", out.encode_pretty()).unwrap();
        println!(
            "wrote reports/bench_influence.json (8-bit fused dispatch vs scalar: {speedup_8bit:.2}x)"
        );
    }

    // multi-query scan: Q validation tasks in ONE datastore pass vs Q
    // sequential single-task passes, at the headline 4-bit precision
    {
        let q = 4usize;
        let (ds, path) = build(4, n, k);
        let tasks_raw: Vec<Vec<FeatureMatrix>> =
            (0..q).map(|t| vec![feats(nv, k, 20 + t as u64)]).collect();
        let refs: Vec<&[FeatureMatrix]> = tasks_raw.iter().map(|t| t.as_slice()).collect();
        let opts = ScoreOpts { mem_budget_mb: 1, ..Default::default() };
        // per-stage cost accounting: the fused pass must read exactly as
        // many shards as ONE single-task scan
        let (_, fused_stats) = score_datastore_tasks(&ds, &refs, opts, None).unwrap();
        let (_, single_stats) = score_datastore_tasks(&ds, &refs[..1], opts, None).unwrap();
        assert_eq!(
            fused_stats.shards_read, single_stats.shards_read,
            "multi-query scan must be one datastore pass"
        );
        println!(
            "multi-query accounting: {q} tasks → {} shard reads (single-task pass: {})",
            fused_stats.shards_read, single_stats.shards_read
        );
        let qpairs = (n * nv * q) as f64;
        let r = bench(&format!("multi_query_fused_4bit (Q={q}, one pass)"), qpairs, "pair", || {
            std::hint::black_box(score_datastore_tasks(&ds, &refs, opts, None).unwrap());
        });
        println!("{}", r.report_line());
        let r = bench(&format!("multi_query_seq_4bit (Q={q}, {q} passes)"), qpairs, "pair", || {
            for t in &refs {
                std::hint::black_box(score_datastore(&ds, t, opts, None).unwrap());
            }
        });
        println!("{}", r.report_line());
        std::fs::remove_file(path).ok();
    }

    // precision cascade (DESIGN.md §10): 1-bit probe over every row, 8-bit
    // rerank over the survivors, vs the exhaustive 8-bit scan the cascade
    // replaces. Reported per multiplier: wall time, bytes actually read,
    // and recall@k against the exhaustive ranking — the EXPERIMENTS.md
    // §Perf cascade rows. At the default multiplier the bytes column must
    // show the ≥2× reduction `tests/cascade.rs` pins.
    {
        use qless::influence::cascade::exhaustive_scan_bytes;
        use qless::influence::{cascade_datastore_tasks, CascadeOpts, DEFAULT_CASCADE_MULT};
        use qless::select::top_k_scored;
        use std::collections::BTreeSet;

        let q = 2usize;
        let k_sel = n / 64; // top ~1.6%, the selection-sized head
        let (ds1, path1) = build(1, n, k); // build() seeds features by (n, k)
        let (ds8, path8) = build(8, n, k); // → the two stores share row space
        let tasks_raw: Vec<Vec<FeatureMatrix>> =
            (0..q).map(|t| vec![feats(nv, k, 40 + t as u64)]).collect();
        let refs: Vec<&[FeatureMatrix]> = tasks_raw.iter().map(|t| t.as_slice()).collect();
        let opts = ScoreOpts { mem_budget_mb: 1, ..Default::default() };
        let exhaustive_bytes = exhaustive_scan_bytes(&ds8.header, n);
        let (all_scores, ex_stats) = score_datastore_tasks(&ds8, &refs, opts, None).unwrap();
        let want: Vec<BTreeSet<usize>> = all_scores
            .iter()
            .map(|s| top_k_scored(s, k_sel).into_iter().map(|(i, _)| i).collect())
            .collect();
        let covering = n.div_ceil(k_sel);
        for mult in [2usize, DEFAULT_CASCADE_MULT, covering] {
            let copts = CascadeOpts { k: k_sel, mult, scan: opts };
            let out = cascade_datastore_tasks(&ds1, &ds8, &refs, copts).unwrap();
            let read = out.combined_pass().bytes_read;
            let recall = want
                .iter()
                .zip(&out.top)
                .map(|(w, got)| got.iter().filter(|(i, _)| w.contains(i)).count() as f64)
                .sum::<f64>()
                / (q * k_sel) as f64;
            let r = bench(
                &format!("cascade_1to8bit (mult={mult}, Q={q}, k_sel={k_sel})"),
                (n * nv * q) as f64,
                "pair",
                || {
                    std::hint::black_box(
                        cascade_datastore_tasks(&ds1, &ds8, &refs, copts).unwrap(),
                    );
                },
            );
            println!(
                "{}  [recall@{k_sel} {recall:.3}, {} read vs {} exhaustive = {:.2}x]",
                r.report_line(),
                human_bytes(read),
                human_bytes(exhaustive_bytes),
                exhaustive_bytes as f64 / read.max(1) as f64,
            );
        }
        let r = bench(
            &format!("cascade_exhaustive_8bit_reference (Q={q})"),
            (n * nv * q) as f64,
            "pair",
            || {
                std::hint::black_box(score_datastore_tasks(&ds8, &refs, opts, None).unwrap());
            },
        );
        println!("{}  [{} read]", r.report_line(), human_bytes(ex_stats.bytes_read));
        std::fs::remove_file(path1).ok();
        std::fs::remove_file(path8).ok();
    }

    // IVF index sweep (PR 10): nclusters × nprobe over a blobbed store —
    // rows/s, recall@k and rows-read reduction vs the exhaustive scan,
    // written to reports/bench_index.json (the EXPERIMENTS.md §Perf
    // iteration 13 numbers). The fixture is clustered on purpose: the
    // index can only route around rows whose sign codes actually separate,
    // and the bench should show the recall/row-traffic trade the
    // tests/index.rs paper-scale case pins, not iid noise.
    {
        use qless::datastore::{build_index, index_path, IndexBuildOpts, LiveStore};
        use qless::influence::{index_scan_live_tasks, score_live_tasks, IndexOpts};
        use qless::select::top_k_scored;
        use std::collections::BTreeSet;

        let (blobs, q, k_sel) = (16usize, 4usize, 32usize);
        let p = Precision::new(4, Scheme::Absmax).unwrap();
        let path = std::env::temp_dir()
            .join(format!("qless_bench_idx_{}.qlds", std::process::id()));
        let mut rng = Rng::new(71);
        let centers: Vec<Vec<f32>> = (0..blobs)
            .map(|_| (0..k).map(|_| 3.0 * rng.normal() as f32).collect())
            .collect();
        let mut w = DatastoreWriter::create(&path, p, n, k, 1).unwrap();
        w.begin_checkpoint(1.0).unwrap();
        let per = n / blobs;
        for i in 0..n {
            let c = &centers[(i / per).min(blobs - 1)];
            let row: Vec<f32> =
                c.iter().map(|&v| v + rng.normal() as f32).collect();
            w.append_features(&row).unwrap();
        }
        w.end_checkpoint().unwrap();
        w.finalize().unwrap();
        let live = LiveStore::open(&path).unwrap();
        let tasks_raw: Vec<Vec<FeatureMatrix>> = (0..q)
            .map(|t| {
                let c = &centers[(t * 5) % blobs];
                let data: Vec<f32> = (0..8 * k)
                    .map(|j| c[j % k] + 0.1 * rng.normal() as f32)
                    .collect();
                vec![FeatureMatrix { n: 8, k, data }]
            })
            .collect();
        let refs: Vec<&[FeatureMatrix]> = tasks_raw.iter().map(|t| t.as_slice()).collect();
        let opts = ScoreOpts { mem_budget_mb: 1, ..Default::default() };
        let (scores, exh) = score_live_tasks(&live, &refs, opts).unwrap();
        let want: Vec<BTreeSet<usize>> = scores
            .iter()
            .map(|s| top_k_scored(s, k_sel).into_iter().map(|(i, _)| i).collect())
            .collect();
        println!(
            "-- index sweep: {n}×{k} 4-bit blobbed store ({blobs} blobs), Q={q}, \
             k_sel={k_sel}, exhaustive {} rows read --",
            exh.rows_read
        );
        let mut sections: Vec<Json> = Vec::new();
        for nclusters in [16usize, 64] {
            let t_build = std::time::Instant::now();
            let idx =
                build_index(&live, &IndexBuildOpts { n_clusters: nclusters, max_iters: 0 })
                    .unwrap();
            let build_s = t_build.elapsed().as_secs_f64();
            println!(
                "index nclusters={nclusters}: built {} clusters in {:.1}ms",
                idx.n_clusters(),
                build_s * 1e3
            );
            let mut probes: Vec<usize> = [1usize, 2, 4, 8, nclusters]
                .into_iter()
                .filter(|&p| p <= nclusters)
                .collect();
            probes.dedup();
            for nprobe in probes {
                let iopts = IndexOpts { k: k_sel, nprobe, scan: opts };
                let out = index_scan_live_tasks(&live, &idx, &refs, &iopts).unwrap();
                let rows_read = out.scan_pass.rows_read;
                let recall = want
                    .iter()
                    .zip(&out.top)
                    .map(|(w, got)| got.iter().filter(|(i, _)| w.contains(i)).count() as f64)
                    .sum::<f64>()
                    / (q * k_sel) as f64;
                let r = bench(
                    &format!("index_scan_4bit (C={nclusters}, nprobe={nprobe})"),
                    rows_read.max(1) as f64,
                    "row",
                    || {
                        std::hint::black_box(
                            index_scan_live_tasks(&live, &idx, &refs, &iopts).unwrap(),
                        );
                    },
                );
                println!(
                    "{}  [recall@{k_sel} {recall:.3}, {} of {} rows read = {:.2}x less]",
                    r.report_line(),
                    rows_read,
                    exh.rows_read,
                    exh.rows_read as f64 / rows_read.max(1) as f64,
                );
                let mut j = Json::obj();
                j.set("section", "index_sweep")
                    .set("nclusters", nclusters)
                    .set("nprobe", nprobe)
                    .set("build_s", build_s)
                    .set("rows_per_s", r.throughput())
                    .set("recall_at_k", recall)
                    .set("rows_read", rows_read as usize)
                    .set("scanned_rows", out.scanned_rows)
                    .set(
                        "reduction_vs_exhaustive",
                        exh.rows_read as f64 / rows_read.max(1) as f64,
                    );
                sections.push(j);
            }
        }
        let mut out = Json::obj();
        out.set("bench", "bench_index")
            .set("n_rows", n)
            .set("k", k)
            .set("blobs", blobs)
            .set("q_tasks", q)
            .set("k_sel", k_sel)
            .set("exhaustive_rows_read", exh.rows_read as usize)
            .set("sections", sections);
        std::fs::create_dir_all("reports").unwrap();
        std::fs::write("reports/bench_index.json", out.encode_pretty()).unwrap();
        println!("wrote reports/bench_index.json");
        std::fs::remove_file(index_path(&path)).ok();
        std::fs::remove_file(&path).ok();
    }

    // the k=8192 regression shape (paper-scale projection dim): the seed
    // popcount kernel panicked here; now it must simply be fast
    {
        let (n8, k8) = (2048usize, 8192usize);
        let (ds, path) = build(1, n8, k8);
        let block = ds.load_checkpoint(0).unwrap();
        let val8 = ValFeatures::prepare(
            &feats(nv, k8, 11),
            Precision::new(1, Scheme::Sign).unwrap(),
        );
        let r = bench("popcount_1bit_k8192", (n8 * nv) as f64, "pair", || {
            std::hint::black_box(scores_1bit(&block, &val8));
        });
        println!("{}", r.report_line());
        std::fs::remove_file(path).ok();
    }

    // paper-scale k for the integer engine too (i32 dot holds to k≈66K)
    {
        let (n8, k8) = (2048usize, 8192usize);
        let (ds, path) = build(4, n8, k8);
        let block = ds.load_checkpoint(0).unwrap();
        let val8 = ValFeatures::prepare(
            &feats(nv, k8, 13),
            Precision::new(4, Scheme::Absmax).unwrap(),
        );
        let r = bench("int_4bit_k8192", (n8 * nv) as f64, "pair", || {
            std::hint::black_box(scores_int_rows(&block.rows(), &val8));
        });
        println!("{}", r.report_line());
        std::fs::remove_file(path).ok();
    }

    // resident query service (qless serve): queries/sec and latency vs the
    // micro-batch window, at Q concurrent clients, cold vs warm shard
    // cache. Score cache disabled so every query pays a real scan; each
    // (client, round) uses distinct val features for the same reason.
    {
        use qless::service::{Client, ServeOpts, Server};
        use qless::util::stats::fmt_secs;
        use std::sync::{Arc, Barrier};

        let nv_serve = 8usize;
        let rounds = 6usize;
        let (_ds, store_path) = build(4, n, k);
        println!("-- serve: {n}×{k} 4-bit store, {nv_serve} val rows/query, {rounds} rounds --");
        for &(q, window_ms) in &[(1usize, 0u64), (4, 0), (4, 2), (16, 2)] {
            let server = Server::start(
                &store_path,
                ServeOpts {
                    addr: "127.0.0.1:0".into(),
                    batch_window_ms: window_ms,
                    max_batch_tasks: 32,
                    shard_rows: 0,
                    mem_budget_mb: 64,
                    score_cache_entries: 0,
                    workers: q + 2,
                    queue_cap: 256,
                },
            )
            .unwrap();
            let addr = server.addr();
            let barrier = Arc::new(Barrier::new(q));
            let t_all = std::time::Instant::now();
            let handles: Vec<_> = (0..q)
                .map(|ci| {
                    let barrier = Arc::clone(&barrier);
                    std::thread::spawn(move || {
                        let mut client = Client::connect(addr).unwrap();
                        let mut lat: Vec<(f64, bool)> = Vec::with_capacity(rounds);
                        barrier.wait();
                        for r in 0..rounds {
                            let val = vec![feats(nv_serve, k, (3000 + ci * 100 + r) as u64)];
                            let t = std::time::Instant::now();
                            client.score(&val, 10, false).unwrap();
                            lat.push((t.elapsed().as_secs_f64(), r == 0));
                        }
                        lat
                    })
                })
                .collect();
            let all: Vec<(f64, bool)> =
                handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
            let wall = t_all.elapsed().as_secs_f64();
            let stats = server.stats();
            server.stop();
            server.join().unwrap();
            let cold: Vec<f64> = all.iter().filter(|(_, c)| *c).map(|(s, _)| *s).collect();
            let mut warm: Vec<f64> = all.iter().filter(|(_, c)| !*c).map(|(s, _)| *s).collect();
            warm.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let pct = |p: f64| -> f64 {
                warm[((p * (warm.len() - 1) as f64).round() as usize).min(warm.len() - 1)]
            };
            let cold_mean = cold.iter().sum::<f64>() / cold.len().max(1) as f64;
            // true per-pass fusion: scanned queries over passes (a per-query
            // mean of `batched` would overweight the big batches)
            let fuse: f64 = if stats.fused_passes > 0 {
                (stats.queries - stats.score_cache_hits) as f64 / stats.fused_passes as f64
            } else {
                0.0
            };
            println!(
                "serve Q={q:<2} window={window_ms}ms: {:>7.1} q/s  cold {:>9}  warm p50 {:>9}  p99 {:>9}  \
                 (avg {fuse:.1} tasks/pass, {} passes, {} disk shard reads)",
                all.len() as f64 / wall,
                fmt_secs(cold_mean),
                fmt_secs(pct(0.50)),
                fmt_secs(pct(0.99)),
                stats.fused_passes,
                stats.disk_shard_reads,
            );
        }
        std::fs::remove_file(store_path).ok();
    }

    // XLA Pallas-tile path (needs artifacts)
    let art = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if art.join("manifest.json").exists() {
        let rt = qless::runtime::Runtime::new(&art).unwrap();
        let info = rt.model("small").unwrap(); // k = 512 matches
        if info.proj_dim == k {
            let (ds, path) = build(8, n, k);
            paths.push(path);
            let block = ds.load_checkpoint(0).unwrap();
            let val = ValFeatures::prepare(&vraw, Precision::new(8, Scheme::Absmax).unwrap());
            let r = bench("xla_pallas_tile_8bit", pairs, "pair", || {
                std::hint::black_box(
                    qless::influence::xla::scores_xla(&rt, &info, &block, &val).unwrap(),
                );
            });
            println!("{}", r.report_line());
        }
    } else {
        println!("(xla path skipped: artifacts not built)");
    }
    for p in paths {
        std::fs::remove_file(p).ok();
    }
}
