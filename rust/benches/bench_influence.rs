//! Influence-scoring throughput: the scoring paths over the same
//! datastore — the dequantize-to-f32 reference, the integer-domain engine
//! (2/4/8-bit), the packed 1-bit XNOR+popcount kernel, the XLA Pallas
//! tile, and the batched multi-query scan. This is the §Perf centerpiece:
//! every sub-16-bit path must beat the f32 reference because it touches a
//! fraction of the memory and does integer math in the hot loop, and Q
//! validation tasks must cost ~one single-task pass, not Q.

use std::path::PathBuf;

use qless::datastore::{Datastore, DatastoreWriter};
use qless::grads::FeatureMatrix;
use qless::influence::native::{scores_1bit, scores_dense, scores_int_rows, ValFeatures};
use qless::influence::{score_datastore, score_datastore_tasks, ScoreOpts};
use qless::quant::{Precision, Scheme};
use qless::util::stats::bench;
use qless::util::table::human_bytes;
use qless::util::Rng;

fn feats(n: usize, k: usize, seed: u64) -> FeatureMatrix {
    let mut rng = Rng::new(seed);
    FeatureMatrix { n, k, data: (0..n * k).map(|_| rng.normal() as f32).collect() }
}

fn build(bits: u8, n: usize, k: usize) -> (Datastore, PathBuf) {
    let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
    let p = Precision::new(bits, scheme).unwrap();
    let path = std::env::temp_dir().join(format!("qless_bench_inf_{bits}_{}.qlds", std::process::id()));
    let f = feats(n, k, 7);
    let mut w = DatastoreWriter::create(&path, p, n, k, 1).unwrap();
    w.begin_checkpoint(1.0).unwrap();
    for i in 0..n {
        w.append_features(f.row(i)).unwrap();
    }
    w.end_checkpoint().unwrap();
    w.finalize().unwrap();
    (Datastore::open(&path).unwrap(), path)
}

fn main() {
    let (n, k, nv) = (4096usize, 512usize, 32usize);
    let pairs = (n * nv) as f64;
    let vraw = feats(nv, k, 9);
    println!("== bench_influence: {n} train × {nv} val × k={k} (one checkpoint) ==");

    let mut paths = Vec::new();
    for bits in [16u8, 8, 4, 2, 1] {
        let (ds, path) = build(bits, n, k);
        paths.push(path);
        let block = ds.load_checkpoint(0).unwrap();
        let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
        let val = ValFeatures::prepare(&vraw, Precision::new(bits, scheme).unwrap());
        let r = bench(&format!("dense_{bits}bit (f32 reference)"), pairs, "pair", || {
            std::hint::black_box(scores_dense(&block, &val));
        });
        println!("{}", r.report_line());
        if matches!(bits, 2 | 4 | 8) {
            // the integer-domain engine: same scores (±1e-5), stored-code
            // dot + zero-point fixup, no dequantize/normalize in the loop
            let r = bench(&format!("int_{bits}bit"), pairs, "pair", || {
                std::hint::black_box(scores_int_rows(&block.rows(), &val));
            });
            println!("{}", r.report_line());
        }
        if bits == 1 {
            let r = bench("popcount_1bit", pairs, "pair", || {
                std::hint::black_box(scores_1bit(&block, &val));
            });
            println!("{}", r.report_line());
        }

        // streamed scan: same scores, O(shard) resident instead of O(block)
        let rows_per_shard = ds.rows_per_shard(0, 1); // 1 MiB budget
        let resident = rows_per_shard as u64 * ds.header.resident_row_bytes();
        let r = bench(
            &format!(
                "streamed_{bits}bit ({} resident vs {} block)",
                human_bytes(resident),
                human_bytes(ds.header.block_bytes()),
            ),
            pairs,
            "pair",
            || {
                std::hint::black_box(
                    score_datastore(
                        &ds,
                        std::slice::from_ref(&vraw),
                        ScoreOpts { mem_budget_mb: 1, ..Default::default() },
                        None,
                    )
                    .unwrap(),
                );
            },
        );
        println!("{}", r.report_line());
    }

    // multi-query scan: Q validation tasks in ONE datastore pass vs Q
    // sequential single-task passes, at the headline 4-bit precision
    {
        let q = 4usize;
        let (ds, path) = build(4, n, k);
        let tasks_raw: Vec<Vec<FeatureMatrix>> =
            (0..q).map(|t| vec![feats(nv, k, 20 + t as u64)]).collect();
        let refs: Vec<&[FeatureMatrix]> = tasks_raw.iter().map(|t| t.as_slice()).collect();
        let opts = ScoreOpts { mem_budget_mb: 1, ..Default::default() };
        // per-stage cost accounting: the fused pass must read exactly as
        // many shards as ONE single-task scan
        let (_, fused_stats) = score_datastore_tasks(&ds, &refs, opts, None).unwrap();
        let (_, single_stats) = score_datastore_tasks(&ds, &refs[..1], opts, None).unwrap();
        assert_eq!(
            fused_stats.shards_read, single_stats.shards_read,
            "multi-query scan must be one datastore pass"
        );
        println!(
            "multi-query accounting: {q} tasks → {} shard reads (single-task pass: {})",
            fused_stats.shards_read, single_stats.shards_read
        );
        let qpairs = (n * nv * q) as f64;
        let r = bench(&format!("multi_query_fused_4bit (Q={q}, one pass)"), qpairs, "pair", || {
            std::hint::black_box(score_datastore_tasks(&ds, &refs, opts, None).unwrap());
        });
        println!("{}", r.report_line());
        let r = bench(&format!("multi_query_seq_4bit (Q={q}, {q} passes)"), qpairs, "pair", || {
            for t in &refs {
                std::hint::black_box(score_datastore(&ds, t, opts, None).unwrap());
            }
        });
        println!("{}", r.report_line());
        std::fs::remove_file(path).ok();
    }

    // the k=8192 regression shape (paper-scale projection dim): the seed
    // popcount kernel panicked here; now it must simply be fast
    {
        let (n8, k8) = (2048usize, 8192usize);
        let (ds, path) = build(1, n8, k8);
        let block = ds.load_checkpoint(0).unwrap();
        let val8 = ValFeatures::prepare(
            &feats(nv, k8, 11),
            Precision::new(1, Scheme::Sign).unwrap(),
        );
        let r = bench("popcount_1bit_k8192", (n8 * nv) as f64, "pair", || {
            std::hint::black_box(scores_1bit(&block, &val8));
        });
        println!("{}", r.report_line());
        std::fs::remove_file(path).ok();
    }

    // paper-scale k for the integer engine too (i32 dot holds to k≈66K)
    {
        let (n8, k8) = (2048usize, 8192usize);
        let (ds, path) = build(4, n8, k8);
        let block = ds.load_checkpoint(0).unwrap();
        let val8 = ValFeatures::prepare(
            &feats(nv, k8, 13),
            Precision::new(4, Scheme::Absmax).unwrap(),
        );
        let r = bench("int_4bit_k8192", (n8 * nv) as f64, "pair", || {
            std::hint::black_box(scores_int_rows(&block.rows(), &val8));
        });
        println!("{}", r.report_line());
        std::fs::remove_file(path).ok();
    }

    // XLA Pallas-tile path (needs artifacts)
    let art = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if art.join("manifest.json").exists() {
        let rt = qless::runtime::Runtime::new(&art).unwrap();
        let info = rt.model("small").unwrap(); // k = 512 matches
        if info.proj_dim == k {
            let (ds, path) = build(8, n, k);
            paths.push(path);
            let block = ds.load_checkpoint(0).unwrap();
            let val = ValFeatures::prepare(&vraw, Precision::new(8, Scheme::Absmax).unwrap());
            let r = bench("xla_pallas_tile_8bit", pairs, "pair", || {
                std::hint::black_box(
                    qless::influence::xla::scores_xla(&rt, &info, &block, &val).unwrap(),
                );
            });
            println!("{}", r.report_line());
        }
    } else {
        println!("(xla path skipped: artifacts not built)");
    }
    for p in paths {
        std::fs::remove_file(p).ok();
    }
}
