//! PJRT runtime micro-benches: per-execute overhead, host-arg vs
//! persistent-buffer calls, and the relative cost of each AOT graph — the
//! numbers that justify the persistent-operand design (§Perf L2/L3).

use std::path::PathBuf;

use qless::corpus::{generate_corpus, Tokenizer};
use qless::data::{Batcher, Dataset};
use qless::model::{init_base, init_lora};
use qless::runtime::{Arg, Runtime};
use qless::util::stats::bench_cfg;

fn main() {
    let art = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !art.join("manifest.json").exists() {
        println!("bench_runtime skipped: run `make artifacts` first");
        return;
    }
    let rt = Runtime::new(&art).unwrap();
    for model in ["tiny", "small"] {
        let info = rt.model(model).unwrap();
        let tok = Tokenizer::default();
        let data = Dataset::encode(
            generate_corpus(info.batch_grad, 1, &tok, info.seq),
            &tok,
            info.seq,
        );
        let batch = Batcher::sequential(&data, info.batch_grad).next().unwrap();
        let base = init_base(&info, 1);
        let lora = init_lora(&info, 1);
        let proj = qless::grads::Projector::new(1, info.d_lora, info.proj_dim);
        println!(
            "== bench_runtime [{model}]: d_base={} d_lora={} k={} B={} ==",
            info.d_base, info.d_lora, info.proj_dim, info.batch_grad
        );

        // host-literal path: every operand re-uploaded per call
        let exec = rt.exec(&info, "grad_val").unwrap();
        let samples = info.batch_grad as f64;
        let r = bench_cfg("grad_val host-args (upload R every call)", samples, "sample", 1, 3, 2.0, &mut || {
            std::hint::black_box(
                exec.run(&[
                    Arg::F32(&base, &[info.d_base]),
                    Arg::F32(&lora, &[info.d_lora]),
                    Arg::I32(&batch.tokens, &[info.batch_grad, info.seq]),
                    Arg::F32(&batch.masks, &[info.batch_grad, info.seq]),
                    Arg::F32(&proj.matrix, &[info.d_lora, info.proj_dim]),
                ])
                .unwrap(),
            );
        });
        println!("{}", r.report_line());

        // persistent-buffer path: checkpoint-lifetime operands resident
        let base_b = rt.upload_f32(&base, &[info.d_base]).unwrap();
        let lora_b = rt.upload_f32(&lora, &[info.d_lora]).unwrap();
        let proj_b = rt.upload_f32(&proj.matrix, &[info.d_lora, info.proj_dim]).unwrap();
        let r = bench_cfg("grad_val persistent buffers", samples, "sample", 1, 3, 2.0, &mut || {
            let tok_b = rt.upload_i32(&batch.tokens, &[info.batch_grad, info.seq]).unwrap();
            let mask_b = rt.upload_f32(&batch.masks, &[info.batch_grad, info.seq]).unwrap();
            std::hint::black_box(
                exec.run_b(&[&base_b, &lora_b, &tok_b, &mask_b, &proj_b]).unwrap(),
            );
        });
        println!("{}", r.report_line());

        // loss_eval + decode_step (the eval hot path)
        let exec_le = rt.exec(&info, "loss_eval").unwrap();
        let data_e = Dataset::encode(
            generate_corpus(info.batch_eval, 2, &tok, info.seq),
            &tok,
            info.seq,
        );
        let batch_e = Batcher::sequential(&data_e, info.batch_eval).next().unwrap();
        let r = bench_cfg("loss_eval", info.batch_eval as f64, "sample", 1, 3, 2.0, &mut || {
            let tok_b = rt.upload_i32(&batch_e.tokens, &[info.batch_eval, info.seq]).unwrap();
            let mask_b = rt.upload_f32(&batch_e.masks, &[info.batch_eval, info.seq]).unwrap();
            std::hint::black_box(exec_le.run_b(&[&base_b, &lora_b, &tok_b, &mask_b]).unwrap());
        });
        println!("{}", r.report_line());

        let exec_ds = rt.exec(&info, "decode_step").unwrap();
        let pos = vec![10i32; info.batch_eval];
        let r = bench_cfg("decode_step (one token, full batch)", info.batch_eval as f64, "tok", 1, 3, 2.0, &mut || {
            let tok_b = rt.upload_i32(&batch_e.tokens, &[info.batch_eval, info.seq]).unwrap();
            let pos_b = rt.upload_i32(&pos, &[info.batch_eval]).unwrap();
            std::hint::black_box(exec_ds.run_b(&[&base_b, &lora_b, &tok_b, &pos_b]).unwrap());
        });
        println!("{}", r.report_line());
    }
}
