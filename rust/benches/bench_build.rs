//! Streaming multi-precision builder throughput: rows/s and peak builder
//! bytes, 1 vs N precisions and 1 vs W quantize workers — the build-side
//! counterpart of `bench_datastore`'s write rows. No model runtime needed:
//! rows are synthetic normals, so this runs anywhere (including CI boxes
//! without `make artifacts`).

use std::path::PathBuf;

use qless::datastore::MultiWriter;
use qless::quant::{Precision, Scheme};
use qless::util::prop::normal_features;
use qless::util::stats::bench_cfg;

fn sweep(bits: &[u8]) -> Vec<Precision> {
    bits.iter()
        .map(|&b| Precision::new(b, if b == 1 { Scheme::Sign } else { Scheme::Absmax }).unwrap())
        .collect()
}

fn main() {
    let (n, k, c) = (4096usize, 512usize, 2usize);
    let window = 256usize;
    let dir = std::env::temp_dir().join(format!("qless_bench_build_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let feats: Vec<_> = (0..c).map(|ci| normal_features(n, k, ci as u64)).collect();
    println!("== bench_build: {n} rows × k={k} × {c} checkpoints, window {window} rows ==");

    let mut run = |label: &str, precisions: &[Precision], workers: usize| {
        let targets: Vec<(Precision, PathBuf)> = precisions
            .iter()
            .map(|p| (*p, dir.join(format!("b_{}b_{}.qlds", p.bits, p.scheme))))
            .collect();
        let mut peak = 0u64;
        let r = bench_cfg(label, (n * c) as f64, "row", 1, 3, 0.5, &mut || {
            let mut mw = MultiWriter::create(&targets, n, k, c, workers).unwrap();
            for (ci, f) in feats.iter().enumerate() {
                mw.begin_checkpoint(0.1 * (ci + 1) as f32).unwrap();
                let mut row = 0usize;
                while row < n {
                    let take = window.min(n - row);
                    mw.append_rows(&f.data[row * k..(row + take) * k]).unwrap();
                    row += take;
                }
                mw.end_checkpoint().unwrap();
            }
            peak = mw.peak_builder_bytes();
            std::hint::black_box(mw.finalize().unwrap());
        });
        println!("{}", r.report_line());
        println!(
            "    peak builder bytes: {} (fp32 matrix would be {})",
            qless::util::table::human_bytes(peak),
            qless::util::table::human_bytes((n * k * 4) as u64),
        );
    };

    // 1 vs N precisions, full parallelism
    run("stream_build 1 precision (16-bit)", &sweep(&[16]), 0);
    run("stream_build 1 precision (1-bit)", &sweep(&[1]), 0);
    run("stream_build 5 precisions (16,8,4,2,1)", &sweep(&[16, 8, 4, 2, 1]), 0);

    // worker scaling on the full sweep
    for workers in [1usize, 2, 4, 8] {
        let label = format!("stream_build 5 precisions, {workers} workers");
        run(&label, &sweep(&[16, 8, 4, 2, 1]), workers);
    }

    std::fs::remove_dir_all(&dir).ok();
}
