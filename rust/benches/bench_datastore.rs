//! Datastore write/read throughput per precision — the storage layer the
//! paper's Table 1 column measures in GB; here we measure it in GB/s.

use qless::datastore::{Datastore, DatastoreWriter};
use qless::grads::FeatureMatrix;
use qless::quant::{Precision, Scheme};
use qless::util::stats::bench_cfg;
use qless::util::Rng;

fn main() {
    let (n, k, c) = (2048usize, 512usize, 2usize);
    let mut rng = Rng::new(3);
    let feats = FeatureMatrix {
        n,
        k,
        data: (0..n * k).map(|_| rng.normal() as f32).collect(),
    };
    let in_bytes = (n * k * 4 * c) as f64;
    println!("== bench_datastore: {n} rows × k={k} × {c} checkpoints ==");

    for bits in [16u8, 8, 4, 2, 1] {
        let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
        let p = Precision::new(bits, scheme).unwrap();
        let path = std::env::temp_dir().join(format!("qless_bench_ds_{bits}.qlds"));
        let r = bench_cfg(
            &format!("write_{bits}bit (quantize+pack+io)"),
            in_bytes,
            "B",
            1,
            3,
            0.5,
            &mut || {
                let mut w = DatastoreWriter::create(&path, p, n, k, c).unwrap();
                for ci in 0..c {
                    w.begin_checkpoint(0.1 * (ci + 1) as f32).unwrap();
                    for i in 0..n {
                        w.append_features(feats.row(i)).unwrap();
                    }
                    w.end_checkpoint().unwrap();
                }
                std::hint::black_box(w.finalize().unwrap());
            },
        );
        println!("{}", r.report_line());

        let ds = Datastore::open(&path).unwrap();
        let file_bytes = ds.file_bytes() as f64;
        let r = bench_cfg(
            &format!("read_{bits}bit (block load)"),
            file_bytes,
            "B",
            1,
            5,
            0.5,
            &mut || {
                for ci in 0..c {
                    std::hint::black_box(ds.load_checkpoint(ci).unwrap());
                }
            },
        );
        println!("{}", r.report_line());

        // streamed read: same bytes, O(shard) resident — the scan path
        let rows_per_shard = ds.rows_per_shard(0, 1).min(256);
        let r = bench_cfg(
            &format!("read_{bits}bit (sharded stream, ≤{rows_per_shard} rows resident)"),
            file_bytes,
            "B",
            1,
            5,
            0.5,
            &mut || {
                for ci in 0..c {
                    let mut sr = ds.shard_reader(ci, rows_per_shard).unwrap();
                    while let Some(shard) = sr.next_shard().unwrap() {
                        std::hint::black_box(shard.rows().data.len());
                    }
                }
            },
        );
        println!("{}", r.report_line());

        let block = ds.load_checkpoint(0).unwrap();
        let r = bench_cfg(
            &format!("dequantize_{bits}bit (all rows)"),
            (n * k * 4) as f64,
            "B",
            1,
            3,
            0.5,
            &mut || {
                for i in 0..n {
                    std::hint::black_box(block.row_f32(i));
                }
            },
        );
        println!("{}", r.report_line());
        std::fs::remove_file(&path).ok();
    }
}
