//! Quantization + packing throughput (the datastore write hot path).
//!
//! Custom harness (criterion is not in the offline vendor set): see
//! `qless::util::stats::bench`. Run with `cargo bench`.

use qless::quant::pack::{pack_codes, unpack_codes};
use qless::quant::scheme::{quantize_row, Scheme};
use qless::util::stats::bench;
use qless::util::Rng;

fn main() {
    let k = 512usize;
    let rows = 256usize;
    let mut rng = Rng::new(1);
    let data: Vec<Vec<f32>> = (0..rows)
        .map(|_| (0..k).map(|_| rng.normal() as f32).collect())
        .collect();
    let bytes_per_iter = (rows * k * 4) as f64;

    println!("== bench_quant: {rows} rows × k={k} fp32 in ==");
    for (bits, scheme) in [
        (8u8, Scheme::Absmax),
        (4, Scheme::Absmax),
        (4, Scheme::Absmean),
        (2, Scheme::Absmax),
        (1, Scheme::Sign),
    ] {
        let r = bench(
            &format!("quantize_{bits}bit_{scheme}"),
            bytes_per_iter,
            "B",
            || {
                for row in &data {
                    std::hint::black_box(quantize_row(row, bits, scheme));
                }
            },
        );
        println!("{}", r.report_line());
    }

    // pack / unpack round trip
    let quantized: Vec<_> = data.iter().map(|r| quantize_row(r, 4, Scheme::Absmax)).collect();
    let r = bench("pack_4bit", bytes_per_iter / 8.0, "B", || {
        for q in &quantized {
            std::hint::black_box(pack_codes(&q.codes, 4, q.scale).unwrap());
        }
    });
    println!("{}", r.report_line());

    let packed: Vec<_> = quantized
        .iter()
        .map(|q| pack_codes(&q.codes, 4, q.scale).unwrap())
        .collect();
    let r = bench("unpack_4bit", bytes_per_iter / 8.0, "B", || {
        for p in &packed {
            std::hint::black_box(unpack_codes(p));
        }
    });
    println!("{}", r.report_line());

    // quantize+pack at 1-bit — the full QLESS store path per row
    let r = bench("quantize+pack_1bit_full_path", bytes_per_iter, "B", || {
        for row in &data {
            let q = quantize_row(row, 1, Scheme::Sign);
            std::hint::black_box(pack_codes(&q.codes, 1, q.scale).unwrap());
        }
    });
    println!("{}", r.report_line());
}
