//! End-to-end stage timing: one miniature pipeline run with per-stage
//! wall-clock — the Table-1-row cost model, and the worker-scaling curve
//! for gradient extraction.

use std::path::PathBuf;

use qless::config::Config;
use qless::eval::Benchmark;
use qless::grads::extract_train_features;
use qless::pipeline::Pipeline;
use qless::quant::{Precision, Scheme};
use qless::select::select_top_frac;
use qless::util::Timer;

fn main() {
    let art = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !art.join("manifest.json").exists() {
        println!("bench_pipeline skipped: run `make artifacts` first");
        return;
    }
    let mut cfg = Config::default();
    cfg.model = "tiny".into();
    cfg.artifacts = art.to_str().unwrap().into();
    cfg.corpus_size = 1000;
    cfg.warmup_epochs = 2;
    cfg.finetune_epochs = 2;
    cfg.val_per_task = 12;
    cfg.eval_per_task = 32;
    cfg.run_dir = std::env::temp_dir()
        .join(format!("qless_bench_pipe_{}", std::process::id()))
        .to_str()
        .unwrap()
        .into();
    println!("== bench_pipeline: tiny model, {} samples ==", cfg.corpus_size);
    let mut pipe = Pipeline::new(cfg).unwrap();

    let stage = |label: &str, secs: f64| println!("{label:<42} {secs:>8.2}s");

    let t = Timer::start("pretrain");
    pipe.base().unwrap();
    stage("pretrain base (cached after first run)", t.stop());

    let t = Timer::start("warmup");
    let set = pipe.warmup().unwrap();
    stage("warmup (LoRA, 2 epochs, 5%)", t.stop());

    // ONE extraction pass streams both precisions to disk; peak builder
    // memory is the bounded window, not the n × k fp32 matrix
    let sweep = [
        Precision::new(16, Scheme::Absmax).unwrap(),
        Precision::new(1, Scheme::Sign).unwrap(),
    ];
    let t = Timer::start("build");
    let stores = pipe.build_datastores(&sweep).unwrap();
    let build_secs = t.stop();
    stage(
        &format!(
            "stream-build 16+1-bit datastores ({} + {} B, one pass)",
            stores[0].1, stores[1].1
        ),
        build_secs,
    );
    let build = pipe.stages.cost(qless::pipeline::Stage::BuildDatastore);
    println!(
        "  peak builder memory: {} (window-bounded, independent of corpus size)",
        qless::util::table::human_bytes(build.io_units)
    );

    let (ds, _) = pipe.build_datastore(Precision::new(1, Scheme::Sign).unwrap()).unwrap();
    let t = Timer::start("score");
    let scores = pipe.influence_scores(&ds, Benchmark::SynArith).unwrap();
    stage("influence scoring (1-bit popcount)", t.stop());
    // the scan streams shards under the config budget instead of
    // materializing the whole checkpoint block:
    let rows = ds.rows_per_shard(pipe.cfg.shard_rows, pipe.cfg.mem_budget_mb);
    println!(
        "  scan resident: {} ({} rows/shard) vs {} whole-block",
        qless::util::table::human_bytes(rows as u64 * ds.header.resident_row_bytes()),
        rows,
        qless::util::table::human_bytes(ds.header.block_bytes()),
    );

    let sel = select_top_frac(&scores, 0.05);
    let t = Timer::start("finetune");
    let (lora, _) = pipe.finetune(&sel, 1).unwrap();
    stage("fine-tune on top-5%", t.stop());

    let t = Timer::start("eval");
    pipe.evaluate_lora(&lora).unwrap();
    stage("3-benchmark eval", t.stop());

    println!("\nstage-runner accounting (wall-clock + cache hits):");
    print!("{}", pipe.stage_table().render());

    // worker scaling for extraction (fresh features each time)
    println!("\nextraction worker scaling (one checkpoint):");
    let ckpt = &set.checkpoints[0];
    let proj = pipe.projector();
    for workers in [1usize, 2, 4, 8] {
        let t = Timer::start("w");
        extract_train_features(
            &pipe.rt,
            &pipe.info,
            &set.base,
            ckpt,
            &pipe.corpus,
            &proj,
            workers,
        )
        .unwrap();
        let secs = t.stop();
        println!(
            "  workers={workers}: {secs:.2}s ({:.0} samples/s)",
            pipe.corpus.len() as f64 / secs
        );
    }
    std::fs::remove_dir_all(pipe.run_dir()).ok();
}
