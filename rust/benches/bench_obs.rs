//! Observability overhead — the numbers behind the "<2% when disabled"
//! acceptance line in EXPERIMENTS.md §Perf, measured three ways:
//!
//! * **span guard, tracing off** — what every instrumented seam pays
//!   when nobody is tracing: one relaxed atomic load and an early
//!   return (no clock read, no lock, no allocation). This is the
//!   disabled path the acceptance bound is about.
//! * **span guard, tracing on** — the enabled cost: two clock reads
//!   plus a bounded ring push under a mutex, paid only while
//!   `--traces` / a traced request is live.
//! * **counter_add** — a registry counter bump. The scan seam emits
//!   one per *pass* (never per row), the caches one per lookup, so
//!   even a microsecond here would vanish in scan time.
//! * **fused scan, tracing off vs on** — the end-to-end check: a real
//!   multi-task scan over a 4-bit store with the registry live, then
//!   the identical scan with span recording enabled, and the relative
//!   overhead between them.

use qless::datastore::DatastoreWriter;
use qless::datastore::Datastore;
use qless::grads::FeatureMatrix;
use qless::influence::{score_datastore_tasks, ScoreOpts};
use qless::quant::{Precision, Scheme};
use qless::util::obs;
use qless::util::stats::bench_cfg;
use qless::util::Rng;

fn feats(n: usize, k: usize, seed: u64) -> FeatureMatrix {
    let mut rng = Rng::new(seed);
    FeatureMatrix { n, k, data: (0..n * k).map(|_| rng.normal() as f32).collect() }
}

fn main() {
    let (n, k, c) = (4096usize, 256usize, 2usize);
    println!("== bench_obs: span/counter primitives + fused-scan overhead ==");

    // -- primitives ----------------------------------------------------
    const CALLS: usize = 100_000;
    obs::set_tracing(false);
    let off = bench_cfg("span guard (tracing off)", CALLS as f64, "call", 2, 5, 0.5, &mut || {
        for _ in 0..CALLS {
            std::hint::black_box(obs::span("bench.noop"));
        }
    });
    println!("{}", off.report_line());
    println!("    ≈ {:.2} ns/call disabled", off.secs.mean / CALLS as f64 * 1e9);

    obs::set_tracing(true);
    let on = bench_cfg("span guard (tracing on, ring write)", CALLS as f64, "call", 2, 5, 0.5, &mut || {
        for _ in 0..CALLS {
            std::hint::black_box(obs::span("bench.noop"));
        }
    });
    obs::set_tracing(false);
    println!("{}", on.report_line());
    println!("    ≈ {:.2} ns/call enabled", on.secs.mean / CALLS as f64 * 1e9);

    let ctr = bench_cfg("counter_add (global registry)", CALLS as f64, "call", 2, 5, 0.5, &mut || {
        for _ in 0..CALLS {
            obs::counter_add("bench_obs_ops_total", 1);
        }
    });
    println!("{}", ctr.report_line());

    // -- end-to-end: the fused scan, off vs on -------------------------
    let p = Precision::new(4, Scheme::Absmax).unwrap();
    let path = std::env::temp_dir().join(format!("qless_bench_obs_{}.qlds", std::process::id()));
    let f = feats(n, k, 11);
    let mut w = DatastoreWriter::create(&path, p, n, k, c).unwrap();
    for ci in 0..c {
        w.begin_checkpoint(0.1 * (ci + 1) as f32).unwrap();
        for i in 0..n {
            w.append_features(f.row(i)).unwrap();
        }
        w.end_checkpoint().unwrap();
    }
    w.finalize().unwrap();
    let ds = Datastore::open(&path).unwrap();

    let tasks: Vec<Vec<FeatureMatrix>> =
        (0..4).map(|t| (0..c).map(|ci| feats(8, k, 50 + t + 10 * ci as u64)).collect()).collect();
    let refs: Vec<&[FeatureMatrix]> = tasks.iter().map(|t| t.as_slice()).collect();
    let opts = ScoreOpts { mem_budget_mb: 8, ..Default::default() };

    obs::set_tracing(false);
    let scan_off = bench_cfg("fused scan 4-bit (tracing off)", (n * c) as f64, "row", 1, 5, 1.0, &mut || {
        std::hint::black_box(score_datastore_tasks(&ds, &refs, opts, None).unwrap());
    });
    println!("{}", scan_off.report_line());

    obs::set_tracing(true);
    let scan_on = bench_cfg("fused scan 4-bit (tracing on)", (n * c) as f64, "row", 1, 5, 1.0, &mut || {
        std::hint::black_box(score_datastore_tasks(&ds, &refs, opts, None).unwrap());
    });
    obs::set_tracing(false);
    println!("{}", scan_on.report_line());

    let rel = (scan_on.secs.mean / scan_off.secs.mean - 1.0) * 100.0;
    println!(
        "tracing-on scan overhead vs off: {rel:+.2}%  (acceptance bounds the *disabled* \
         path at <2%; its per-seam cost is the span-guard line above)"
    );
    std::fs::remove_file(&path).ok();
}
