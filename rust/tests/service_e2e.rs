//! End-to-end suite for the resident query service — the acceptance
//! contract of the serving layer:
//!
//! * N concurrent TCP clients with overlapping tasks get score vectors
//!   **byte-identical** to a direct `score_datastore_tasks` call;
//! * a burst of queries coalesces into **one** fused datastore pass,
//!   asserted via the `ScanStats` every rider of the batch reports;
//! * a repeat query answers from the score cache, and a *new* query over a
//!   warm shard cache scans without touching the datastore file again
//!   (`disk_shard_reads` stays flat);
//! * a property test: batching grouping, shard size and cache hits are
//!   non-semantic — scores never change.

use std::path::PathBuf;
use std::sync::{Arc, Barrier};

use qless::datastore::Datastore;
use qless::grads::FeatureMatrix;
use qless::influence::{score_datastore_tasks, ScoreOpts};
use qless::prop_assert;
use qless::quant::{Precision, Scheme};
use qless::service::{Client, ScoreQuery, ServeOpts, Server, Session, SessionOpts};
use qless::util::prop::{normal_features as feats, run_prop, seeded_datastore};

fn build_store(tag: &str, bits: u8, n: usize, k: usize, etas: &[f32]) -> PathBuf {
    let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
    let p = Precision::new(bits, scheme).unwrap();
    let path = std::env::temp_dir().join(format!(
        "qless_e2e_{tag}_{bits}_{}_{:?}.qlds",
        std::process::id(),
        std::thread::current().id()
    ));
    seeded_datastore(&path, p, n, k, etas, 1000);
    path
}

fn task(k: usize, ckpts: usize, seed: u64) -> Vec<FeatureMatrix> {
    (0..ckpts).map(|ci| feats(2, k, seed * 10 + ci as u64)).collect()
}

/// The acceptance-criteria test: concurrent clients, byte-identical
/// scores, burst coalescing proven by ScanStats, and warm-cache repeat
/// queries that never reread the datastore file.
#[test]
fn concurrent_clients_byte_identical_coalesced_and_warm() {
    let (n, k, shard_rows) = (48usize, 64usize, 7usize);
    let etas = [0.7f32, 0.3];
    let path = build_store("main", 4, n, k, &etas);

    // ground truth: ONE direct fused call on the library path
    let tasks: Vec<Vec<FeatureMatrix>> = (0..3).map(|t| task(k, 2, 10 + t)).collect();
    let ds = Datastore::open(&path).unwrap();
    let refs: Vec<&[FeatureMatrix]> = tasks.iter().map(|t| t.as_slice()).collect();
    let (expected, expected_stats) = score_datastore_tasks(
        &ds,
        &refs,
        ScoreOpts { shard_rows, ..Default::default() },
        None,
    )
    .unwrap();
    let one_pass_shards = 2 * n.div_ceil(shard_rows); // 2 checkpoints
    assert_eq!(expected_stats.shards_read, one_pass_shards);

    let server = Server::start(
        &path,
        ServeOpts {
            addr: "127.0.0.1:0".into(),
            batch_window_ms: 400, // wide: the whole burst must land in one batch
            max_batch_tasks: 16,
            shard_rows,
            mem_budget_mb: 64, // far larger than the store: everything pins
            score_cache_entries: 8,
            workers: 8,
            queue_cap: 64,
        },
    )
    .unwrap();
    let addr = server.addr();

    // 6 concurrent clients, 3 distinct tasks (i % 3): overlapping queries
    let n_clients = 6usize;
    let barrier = Arc::new(Barrier::new(n_clients));
    let tasks = Arc::new(tasks);
    let handles: Vec<_> = (0..n_clients)
        .map(|i| {
            let barrier = Arc::clone(&barrier);
            let tasks = Arc::clone(&tasks);
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).expect("connect");
                barrier.wait(); // fire the burst together
                let r = c.score(&tasks[i % 3], 5, true).expect("score");
                (i, r)
            })
        })
        .collect();
    let replies: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    let first_pass = replies[0].1.pass;
    for (i, r) in &replies {
        // byte-identical to the direct fused library call
        let got = r.scores.as_ref().expect("full scores requested");
        let want = &expected[i % 3];
        assert_eq!(got.len(), want.len());
        for (j, (a, b)) in want.iter().zip(got).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "client {i} sample {j}: served {b} != direct {a}"
            );
        }
        // the per-request top-k is consistent with the full vector
        assert_eq!(r.top, qless::select::top_k_scored(got, 5));
        // the whole burst coalesced: every rider reports the SAME single
        // pass, fusing exactly the 3 distinct tasks
        assert!(!r.cached);
        assert_eq!(r.batched, 3, "client {i}: burst must dedup to 3 fused tasks");
        assert_eq!(r.pass, first_pass, "client {i}: all riders share one pass");
        assert_eq!(r.pass.tasks, 3);
        assert_eq!(
            r.pass.shards_read, one_pass_shards,
            "client {i}: Q queries must cost one datastore traversal"
        );
        assert_eq!(r.generation, server.generation());
    }

    // ---- warm phase -------------------------------------------------------
    let mut c = Client::connect(addr).unwrap();
    let cold = c.stats().unwrap();
    assert_eq!(cold.stats.fused_passes, 1);
    assert_eq!(cold.stats.queries, n_clients as u64);
    assert_eq!(
        cold.stats.disk_shard_reads, one_pass_shards as u64,
        "cold pass read each shard exactly once"
    );

    // repeat query: score cache answers, no scan, no disk
    let r = c.score(&tasks[0], 3, true).unwrap();
    assert!(r.cached, "identical query must hit the score cache");
    for (a, b) in expected[0].iter().zip(r.scores.as_ref().unwrap()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    let s1 = c.stats().unwrap();
    assert_eq!(s1.stats.fused_passes, 1, "cache hit runs no pass");
    assert_eq!(s1.stats.disk_shard_reads, cold.stats.disk_shard_reads);
    assert_eq!(s1.stats.score_cache_hits, 1);

    // NEW task over the warm shard cache: a fused pass that scans entirely
    // from RAM — the datastore file is never read again
    let fresh = task(k, 2, 99);
    let r2 = c.score(&fresh, 0, false).unwrap();
    assert!(!r2.cached);
    assert_eq!(r2.pass.shards_read, one_pass_shards, "full scan, served from RAM");
    let s2 = c.stats().unwrap();
    assert_eq!(s2.stats.fused_passes, 2);
    assert_eq!(
        s2.stats.disk_shard_reads, cold.stats.disk_shard_reads,
        "warm-cache query must not read the datastore file again"
    );
    assert_eq!(s2.stats.shard_cache_hits, one_pass_shards as u64);
    assert!(s2.stats.shard_cache_bytes > 0);

    c.shutdown().unwrap();
    server.join().unwrap();
    std::fs::remove_file(path).ok();
}

/// Batching grouping, shard geometry, and both caches are non-semantic:
/// however queries are grouped into batches, and whether they hit disk,
/// the shard cache, or the score cache, scores equal the direct library
/// scan bit-for-bit — at every bitwidth.
#[test]
fn prop_batching_and_caches_never_change_scores() {
    run_prop("service-batching-invariant", 10, |g| {
        let bits = [1u8, 2, 4, 8, 16][g.rng.below(5)];
        let n = 8 + g.usize_up_to(24);
        let k = 64usize;
        let ckpts = 1 + g.rng.below(2);
        let etas: Vec<f32> = (0..ckpts).map(|i| 0.9 - 0.3 * i as f32).collect();
        let path = build_store("prop", bits, n, k, &etas);

        let q = 1 + g.rng.below(3);
        let tasks: Vec<Vec<FeatureMatrix>> =
            (0..q).map(|t| task(k, ckpts, 500 + t as u64)).collect();
        let refs: Vec<&[FeatureMatrix]> = tasks.iter().map(|t| t.as_slice()).collect();
        let ds = Datastore::open(&path).unwrap();
        let (expected, _) =
            score_datastore_tasks(&ds, &refs, ScoreOpts::default(), None).unwrap();
        drop(ds);

        let opts = SessionOpts {
            shard_rows: 1 + g.rng.below(n + 2),
            mem_budget_mb: 1,
            score_cache_entries: g.rng.below(3), // sometimes disabled
        };
        let mut sess = Session::open(&path, opts).unwrap();
        // several rounds of randomly grouped, randomly repeated queries
        for _round in 0..3 {
            let mut batch: Vec<(usize, ScoreQuery)> = Vec::new();
            let batch_len = 1 + g.rng.below(2 * q);
            for _ in 0..batch_len {
                let t = g.rng.below(q);
                batch.push((t, ScoreQuery { val: tasks[t].clone() }));
            }
            let queries: Vec<ScoreQuery> = batch.iter().map(|(_, s)| s.clone()).collect();
            let answers = sess.answer_batch(&queries).unwrap();
            for ((t, _), a) in batch.iter().zip(&answers) {
                prop_assert!(
                    a.scores.len() == expected[*t].len(),
                    "bits {bits}: score length"
                );
                for (j, (want, got)) in expected[*t].iter().zip(a.scores.iter()).enumerate()
                {
                    prop_assert!(
                        want.to_bits() == got.to_bits(),
                        "bits {bits} task {t} sample {j}: {want} != {got} \
                         (shard_rows {}, cache {})",
                        opts.shard_rows,
                        opts.score_cache_entries
                    );
                }
            }
        }
        std::fs::remove_file(path).ok();
        Ok(())
    });
}

/// A zero-width window still coalesces whatever queued while the previous
/// batch scored, and never changes scores — the low-latency configuration.
#[test]
fn zero_window_server_still_correct_under_concurrency() {
    let (n, k) = (24usize, 64usize);
    let path = build_store("zero", 8, n, k, &[1.0]);
    let tasks: Vec<Vec<FeatureMatrix>> = (0..4).map(|t| task(k, 1, 70 + t)).collect();
    let refs: Vec<&[FeatureMatrix]> = tasks.iter().map(|t| t.as_slice()).collect();
    let ds = Datastore::open(&path).unwrap();
    let (expected, _) = score_datastore_tasks(&ds, &refs, ScoreOpts::default(), None).unwrap();
    drop(ds);

    let server = Server::start(
        &path,
        ServeOpts {
            addr: "127.0.0.1:0".into(),
            batch_window_ms: 0,
            score_cache_entries: 0, // force rescans: correctness under load
            workers: 4,
            ..Default::default()
        },
    )
    .unwrap();
    let addr = server.addr();
    let tasks = Arc::new(tasks);
    let expected = Arc::new(expected);
    let handles: Vec<_> = (0..4usize)
        .map(|i| {
            let tasks = Arc::clone(&tasks);
            let expected = Arc::clone(&expected);
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                for round in 0..3 {
                    let t = (i + round) % 4;
                    let r = c.score(&tasks[t], 2, true).unwrap();
                    let got = r.scores.unwrap();
                    for (a, b) in expected[t].iter().zip(&got) {
                        assert_eq!(a.to_bits(), b.to_bits(), "client {i} round {round}");
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    server.stop();
    server.join().unwrap();
    std::fs::remove_file(path).ok();
}
