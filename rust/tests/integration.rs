//! Integration tests across runtime + grads + quant + datastore + influence.
//!
//! These require built artifacts (`make artifacts`); they skip gracefully
//! when the directory is missing so `cargo test` works on a fresh clone.

use std::path::PathBuf;

use qless::config::Config;
use qless::corpus::{generate_corpus, Tokenizer};
use qless::data::Dataset;
use qless::eval::Benchmark;
use qless::grads::Projector;
use qless::model::{init_base, init_lora, Checkpoint};
use qless::pipeline::Pipeline;
use qless::quant::{datastore_bytes, Precision, Scheme};
use qless::runtime::{Arg, Runtime};
use qless::select::select_top_frac;

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.json").exists().then_some(p)
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(p) => p,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

fn tmp_run_dir(tag: &str) -> String {
    let d = std::env::temp_dir().join(format!("qless_it_{tag}_{}", std::process::id()));
    d.to_str().unwrap().to_string()
}

fn mini_config(tag: &str, artifacts_dir: &PathBuf) -> Config {
    let mut cfg = Config::default();
    cfg.model = "tiny".into();
    cfg.artifacts = artifacts_dir.to_str().unwrap().to_string();
    cfg.run_dir = tmp_run_dir(tag);
    cfg.corpus_size = 400;
    cfg.warmup_epochs = 2;
    cfg.finetune_epochs = 1;
    cfg.val_per_task = 8;
    cfg.eval_per_task = 16;
    cfg.workers = 2;
    cfg
}

/// The AOT train_step must implement textbook Adam: replicate one step on
/// the host from the same inputs and compare the updated LoRA params.
#[test]
fn train_step_is_adam() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let info = rt.model("tiny").unwrap();
    let tok = Tokenizer::default();
    let data = Dataset::encode(generate_corpus(info.batch_train, 3, &tok, info.seq), &tok, info.seq);
    let batch = qless::data::Batcher::sequential(&data, info.batch_train).next().unwrap();

    let base = init_base(&info, 1);
    let lora = init_lora(&info, 1);

    // grad via grad_val with identity-ish projection is unavailable (k<dl),
    // so recover the batch-mean gradient from two train_steps instead:
    // with m=v=0, t=1: update = lr * ghat/(sqrt(ghat^2·c)+eps) — not linear.
    // Simpler: run train_step twice with different lr and check the Adam
    // invariants that ARE linear: m' = (1-β1)·g and v' = (1-β2)·g².
    let exec = rt.exec(&info, "train_step").unwrap();
    let run = |lr: f32| -> (Vec<f32>, Vec<f32>, Vec<f32>, f32) {
        let out = exec
            .run(&[
                Arg::F32(&base, &[info.d_base]),
                Arg::F32(&lora, &[info.d_lora]),
                Arg::F32(&vec![0.0; info.d_lora], &[info.d_lora]),
                Arg::F32(&vec![0.0; info.d_lora], &[info.d_lora]),
                Arg::ScalarF32(1.0),
                Arg::I32(&batch.tokens, &[info.batch_train, info.seq]),
                Arg::F32(&batch.masks, &[info.batch_train, info.seq]),
                Arg::ScalarF32(lr),
            ])
            .unwrap();
        let mut it = out.into_iter();
        let l = it.next().unwrap();
        let m = it.next().unwrap();
        let v = it.next().unwrap();
        let loss = it.next().unwrap()[0];
        (l, m, v, loss)
    };
    let (l1, m1, v1, loss1) = run(1e-3);
    let (l2, m2, v2, loss2) = run(2e-3);
    assert!((loss1 - loss2).abs() < 1e-6, "loss must not depend on lr");
    assert_eq!(m1, m2, "optimizer state must not depend on lr");
    assert_eq!(v1, v2);
    // v' = (1-β2) g² ⇒ g = ±sqrt(v/(1-β2)); m' = (1-β1) g — signs must agree
    let b1 = info.adam_b1 as f32;
    let b2 = info.adam_b2 as f32;
    for i in (0..info.d_lora).step_by(97) {
        let g_from_m = m1[i] / (1.0 - b1);
        let g_from_v = (v1[i] / (1.0 - b2)).sqrt();
        assert!(
            (g_from_m.abs() - g_from_v).abs() <= 2e-2 * g_from_v.max(1e-6) + 1e-6,
            "idx {i}: |g| from m {} vs from v {}",
            g_from_m.abs(),
            g_from_v
        );
    }
    // the update direction doubles with lr: (l2-lora) ≈ 2 (l1-lora)
    let mut num = 0f64;
    let mut den = 0f64;
    for i in 0..info.d_lora {
        let d1 = (l1[i] - lora[i]) as f64;
        let d2 = (l2[i] - lora[i]) as f64;
        num += d2 * d1;
        den += d1 * d1;
    }
    let ratio = num / den.max(1e-30);
    assert!((ratio - 2.0).abs() < 0.01, "update/lr linearity: ratio {ratio}");
}

/// grad_val features must match host-side projection of the implicit
/// gradient: project with R and with 2R — features must exactly double
/// (projection is linear and inside the graph).
#[test]
fn projection_linearity_through_graph() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let info = rt.model("tiny").unwrap();
    let tok = Tokenizer::default();
    let data = Dataset::encode(generate_corpus(info.batch_grad, 5, &tok, info.seq), &tok, info.seq);
    let batch = qless::data::Batcher::sequential(&data, info.batch_grad).next().unwrap();
    let base = init_base(&info, 2);
    let lora = init_lora(&info, 2);
    let proj = Projector::new(7, info.d_lora, info.proj_dim);
    let exec = rt.exec(&info, "grad_val").unwrap();
    let run = |r: &[f32]| -> Vec<f32> {
        exec.run(&[
            Arg::F32(&base, &[info.d_base]),
            Arg::F32(&lora, &[info.d_lora]),
            Arg::I32(&batch.tokens, &[info.batch_grad, info.seq]),
            Arg::F32(&batch.masks, &[info.batch_grad, info.seq]),
            Arg::F32(r, &[info.d_lora, info.proj_dim]),
        ])
        .unwrap()
        .remove(0)
    };
    let f1 = run(&proj.matrix);
    let r2: Vec<f32> = proj.matrix.iter().map(|x| 2.0 * x).collect();
    let f2 = run(&r2);
    for (a, b) in f1.iter().zip(&f2) {
        assert!((2.0 * a - b).abs() <= 1e-4 * b.abs().max(1e-3), "{a} {b}");
    }
}

/// Full selection path at every precision: scores must be finite, bounded,
/// and the 1-bit ranking must correlate strongly with the 16-bit ranking
/// (the paper's core claim at the selection level).
#[test]
fn selection_consistent_across_precisions() {
    let dir = require_artifacts!();
    let cfg = mini_config("sel", &dir);
    let mut pipe = Pipeline::new(cfg).unwrap();
    let (ds16, b16) = pipe.build_datastore(Precision::new(16, Scheme::Absmax).unwrap()).unwrap();
    let (ds1, b1) = pipe.build_datastore(Precision::new(1, Scheme::Sign).unwrap()).unwrap();

    // measured sizes obey the accounting formula exactly
    let n = pipe.corpus.len();
    let k = pipe.info.proj_dim;
    let c = pipe.cfg.warmup_epochs;
    let overhead16 = 36 + 4 * c as u64;
    let overhead1 = overhead16;
    assert_eq!(
        b16 - overhead16,
        datastore_bytes(Precision::new(16, Scheme::Absmax).unwrap(), n, k, c)
    );
    assert_eq!(
        b1 - overhead1,
        datastore_bytes(Precision::new(1, Scheme::Sign).unwrap(), n, k, c)
    );

    for bench in Benchmark::ALL {
        let s16 = pipe.influence_scores(&ds16, bench).unwrap();
        let s1 = pipe.influence_scores(&ds1, bench).unwrap();
        assert_eq!(s16.len(), n);
        assert!(s16.iter().chain(&s1).all(|x| x.is_finite()));
        // rank correlation via top-10% overlap
        let t16 = select_top_frac(&s16, 0.10);
        let t1 = select_top_frac(&s1, 0.10);
        let overlap = t1.iter().filter(|i| t16.contains(i)).count() as f64 / t16.len() as f64;
        assert!(
            overlap > 0.3,
            "{bench}: 1-bit vs 16-bit top-10% overlap only {overlap:.2}"
        );
    }
    std::fs::remove_dir_all(pipe.run_dir()).ok();
}

/// Selection must strongly over-represent the benchmark-aligned source —
/// the mechanism behind the paper's Fig. 5 and the LESS>random claim.
#[test]
fn selection_targets_aligned_source() {
    let dir = require_artifacts!();
    let cfg = mini_config("align", &dir);
    let mut pipe = Pipeline::new(cfg).unwrap();
    let (ds, _) = pipe.build_datastore(Precision::new(8, Scheme::Absmax).unwrap()).unwrap();
    // SynArith ↔ syncot is the sharpest alignment (format-identical tasks)
    let scores = pipe.influence_scores(&ds, Benchmark::SynArith).unwrap();
    let sel = select_top_frac(&scores, 0.05);
    let dist = qless::select::SourceDistribution::of(&pipe.corpus.samples, &sel);
    let aligned = dist.frac(qless::corpus::Source::SynCot);
    assert!(
        aligned > 0.6,
        "SynArith selection should be dominated by syncot (37% base rate), got {aligned:.2}: {}",
        dist.render()
    );
    std::fs::remove_dir_all(pipe.run_dir()).ok();
}

/// The XLA (Pallas kernel) scoring path and the native path must agree on
/// the final aggregated scores, not just per-tile results.
#[test]
fn xla_and_native_scoring_agree_end_to_end() {
    let dir = require_artifacts!();
    let mut cfg = mini_config("xlanative", &dir);
    cfg.corpus_size = 300;
    let mut pipe = Pipeline::new(cfg).unwrap();
    let (ds, _) = pipe.build_datastore(Precision::new(4, Scheme::Absmax).unwrap()).unwrap();
    let native = pipe.influence_scores(&ds, Benchmark::SynQA).unwrap();
    pipe.cfg.xla_score = true;
    let xla = pipe.influence_scores(&ds, Benchmark::SynQA).unwrap();
    for (i, (a, b)) in native.iter().zip(&xla).enumerate() {
        assert!((a - b).abs() < 1e-4, "sample {i}: native {a} vs xla {b}");
    }
    std::fs::remove_dir_all(pipe.run_dir()).ok();
}

/// Weight quantization (QLoRA ablation) degrades features gracefully:
/// 8-bit features stay close to 16-bit ones, 4-bit drifts more but
/// rankings remain correlated.
#[test]
fn weight_quantization_preserves_feature_geometry() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let info = rt.model("tiny").unwrap();
    let tok = Tokenizer::default();
    let data = Dataset::encode(generate_corpus(32, 9, &tok, info.seq), &tok, info.seq);
    let base = init_base(&info, 3);
    let ckpt = Checkpoint::fresh(info.d_lora, init_lora(&info, 3));
    let proj = Projector::new(11, info.d_lora, info.proj_dim);
    let feats = |bits: u8| {
        let bq = qless::quant::weights::quantize_weights(&base, bits);
        qless::grads::extract_val_features(&rt, &info, &bq, &ckpt, &data, &proj, 2).unwrap()
    };
    let f16 = feats(16);
    let f8 = feats(8);
    // cosine similarity of per-sample features across weight precisions
    let mut cos_sum = 0f64;
    for i in 0..f16.n {
        let a = f16.row(i);
        let b = f8.row(i);
        let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
        let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
        let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
        cos_sum += (dot / (na * nb).max(1e-12)) as f64;
    }
    let mean_cos = cos_sum / f16.n as f64;
    assert!(mean_cos > 0.95, "8-bit weights should barely move features: cos {mean_cos:.3}");
}
