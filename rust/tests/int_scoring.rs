//! Integer-domain scoring equivalence suite (the tentpole's contract).
//!
//! The streamed scan dispatches to the integer-domain engine at 2/4/8-bit
//! and to XNOR+popcount at 1-bit. These properties pin it to the
//! dequantize-to-f32 reference (`scores_dense`):
//!
//! * at 1-bit the kernel's score is **exact**: bit-for-bit equal to an
//!   independently computed i64 code dot with a single final f32
//!   conversion (and within 1e-5 of the f32 reference);
//! * at 2/4/8-bit, for both absmax and absmean, scores match the f32
//!   reference within 1e-5 relative — across dividing and non-dividing
//!   shard sizes, so streaming granularity stays a non-semantic knob;
//! * a fused Q-task scan equals Q single-task scans bit-for-bit while
//!   reading the datastore exactly once ([`ScanStats`] proves the pass).

use std::path::PathBuf;

use qless::datastore::Datastore;
use qless::grads::FeatureMatrix;
use qless::influence::native::{scores_dense, ValFeatures};
use qless::influence::{score_datastore, score_datastore_tasks, ScanStats, ScoreOpts};
use qless::prop_assert;
use qless::quant::{quantize_row, Precision, Scheme};
use qless::util::prop::{normal_features as feats, run_prop, seeded_datastore};

fn tmpfile(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "qless_intscore_{tag}_{}_{:?}.qlds",
        std::process::id(),
        std::thread::current().id()
    ))
}

fn build_store(
    tag: &str,
    precision: Precision,
    n: usize,
    k: usize,
    etas: &[f32],
    seed: u64,
) -> (Datastore, PathBuf) {
    let path = tmpfile(tag);
    (seeded_datastore(&path, precision, n, k, etas, seed), path)
}

/// η-weighted whole-block aggregation over the dequantize-to-f32
/// reference kernel — the scores every integer path is held to.
fn f32_reference_scores(ds: &Datastore, vals: &[FeatureMatrix]) -> Vec<f32> {
    let mut total = vec![0f32; ds.n_samples()];
    for ci in 0..ds.n_checkpoints() {
        let block = ds.load_checkpoint(ci).unwrap();
        let val = ValFeatures::prepare(&vals[ci], block.precision);
        for (t, s) in total.iter_mut().zip(scores_dense(&block, &val)) {
            *t += block.eta * s;
        }
    }
    total
}

/// |a − b| within `tol` relative to max(1, |a|, |b|). Mean cosines are
/// bounded by 1, so the max(1, ·) makes this an absolute bound in
/// practice while staying meaningful for η-amplified totals.
fn close(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() <= tol * a.abs().max(b.abs()).max(1.0)
}

#[test]
fn prop_int_scores_match_f32_reference_all_schemes_and_shards() {
    // scheme × bitwidth × {dividing, non-dividing} shard sizes:
    // the streamed scan (integer kernels) vs the f32 reference.
    run_prop("int-matches-f32", 30, |g| {
        let n = 3 + g.usize_up_to(24);
        let k = 8 * (1 + g.usize_up_to(20)); // up to 168 dims
        let ckpts = 1 + g.rng.below(2);
        let etas: Vec<f32> = (0..ckpts).map(|c| 0.2 + 0.5 * c as f32).collect();
        let seed = g.rng.below(1 << 20) as u64;
        let combos: [(u8, Scheme); 7] = [
            (1, Scheme::Sign),
            (2, Scheme::Absmax),
            (2, Scheme::Absmean),
            (4, Scheme::Absmax),
            (4, Scheme::Absmean),
            (8, Scheme::Absmax),
            (8, Scheme::Absmean),
        ];
        for (bits, scheme) in combos {
            let p = Precision::new(bits, scheme).unwrap();
            let (ds, path) = build_store(&format!("ref{bits}{scheme}"), p, n, k, &etas, seed);
            let vals: Vec<FeatureMatrix> =
                (0..ckpts).map(|c| feats(1 + c, k, seed + 500 + c as u64)).collect();
            let expect = f32_reference_scores(&ds, &vals);
            // shard sizes: 1 and n always divide; n/2+1 never does for n≥3
            for shard_rows in [1usize, n, n / 2 + 1] {
                let got = score_datastore(
                    &ds,
                    &vals,
                    ScoreOpts { shard_rows, ..Default::default() },
                    None,
                )
                .map_err(|e| e.to_string())?;
                for (i, (&a, &b)) in expect.iter().zip(&got).enumerate() {
                    prop_assert!(
                        close(a, b, 1e-5),
                        "{bits}-bit {scheme} n={n} k={k} ckpts={ckpts} shard={shard_rows} \
                         row {i}: reference {a} vs integer-domain {b}"
                    );
                }
            }
            std::fs::remove_file(path).ok();
        }
        Ok(())
    });
}

#[test]
fn prop_1bit_scores_are_integer_exact() {
    // The popcount path must equal an independently computed exact i64
    // code dot (one final f32 conversion) bit-for-bit — the "exact at
    // 1-bit" half of the acceptance contract.
    run_prop("1bit-exact", 30, |g| {
        let n = 2 + g.usize_up_to(20);
        let k = 8 * (1 + g.usize_up_to(24));
        let ckpts = 1 + g.rng.below(2);
        let etas: Vec<f32> = (0..ckpts).map(|c| 0.3 + 0.4 * c as f32).collect();
        let seed = g.rng.below(1 << 20) as u64;
        let p = Precision::new(1, Scheme::Sign).unwrap();
        let (ds, path) = build_store("exact1", p, n, k, &etas, seed);
        let vals: Vec<FeatureMatrix> =
            (0..ckpts).map(|c| feats(1 + g.rng.below(4), k, seed + 900 + c as u64)).collect();

        // exact integer reference, replicating the kernel's final float
        // op sequence: (Σ_v ⟨t,v⟩ as f32 · (1/k)) / nv, then η-weighted
        let inv_k = 1.0 / k as f32;
        let mut expect = vec![0f32; n];
        for ci in 0..ds.n_checkpoints() {
            let block = ds.load_checkpoint(ci).unwrap();
            let val_codes: Vec<Vec<i8>> = (0..vals[ci].n)
                .map(|v| quantize_row(vals[ci].row(v), 1, Scheme::Sign).codes)
                .collect();
            let nv = val_codes.len() as f32;
            for (i, e) in expect.iter_mut().enumerate() {
                let t = block.row_codes(i);
                let mut total_dot = 0i64;
                for v in &val_codes {
                    for (&a, &b) in t.iter().zip(v.iter()) {
                        total_dot += (a as i64) * (b as i64);
                    }
                }
                *e += block.eta * ((total_dot as f32 * inv_k) / nv);
            }
        }

        for shard_rows in [1usize, n, n / 2 + 1] {
            let got = score_datastore(
                &ds,
                &vals,
                ScoreOpts { shard_rows, ..Default::default() },
                None,
            )
            .map_err(|e| e.to_string())?;
            prop_assert!(
                got == expect,
                "1-bit n={n} k={k} shard={shard_rows}: popcount not integer-exact \
                 ({got:?} vs {expect:?})"
            );
        }
        std::fs::remove_file(path).ok();
        Ok(())
    });
}

#[test]
fn prop_multi_task_scan_is_one_pass_and_exact() {
    // Q tasks fused into one scan: per-task scores equal the single-task
    // scans bit-for-bit, and the I/O accounting shows ONE datastore pass
    // regardless of Q.
    run_prop("multi-one-pass", 25, |g| {
        let n = 4 + g.usize_up_to(28);
        let k = 8 * (2 + g.usize_up_to(10));
        let bits = [1u8, 2, 4, 8, 16][g.rng.below(5)];
        let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
        let p = Precision::new(bits, scheme).unwrap();
        let ckpts = 1 + g.rng.below(2);
        let etas: Vec<f32> = (0..ckpts).map(|c| 0.5 + 0.2 * c as f32).collect();
        let seed = g.rng.below(1 << 20) as u64;
        let (ds, path) = build_store(&format!("multi{bits}"), p, n, k, &etas, seed);
        let q = 1 + g.rng.below(3);
        let tasks: Vec<Vec<FeatureMatrix>> = (0..q)
            .map(|t| {
                (0..ckpts)
                    .map(|c| feats(1 + g.rng.below(3), k, seed + (t * 100 + c) as u64 + 1))
                    .collect()
            })
            .collect();
        let refs: Vec<&[FeatureMatrix]> = tasks.iter().map(|t| t.as_slice()).collect();
        let shard_rows = 1 + g.rng.below(n + 2);
        let opts = ScoreOpts { shard_rows, ..Default::default() };
        let (fused, stats) =
            score_datastore_tasks(&ds, &refs, opts, None).map_err(|e| e.to_string())?;
        let expect_shards = n.div_ceil(shard_rows.min(n)) * ckpts;
        prop_assert!(
            stats
                == ScanStats {
                    checkpoints: ckpts,
                    tasks: q,
                    shards_read: expect_shards,
                    rows_read: (n * ckpts) as u64,
                    bytes_read: (n * ckpts) as u64 * ds.header.resident_row_bytes(),
                },
            "stats {stats:?} != one pass of {expect_shards} shards (q={q}, bits={bits})"
        );
        for (t, task) in tasks.iter().enumerate() {
            let alone =
                score_datastore(&ds, task, opts, None).map_err(|e| e.to_string())?;
            prop_assert!(
                alone == fused[t],
                "bits={bits} q={q} task {t}: fused scan differs from single scan"
            );
        }
        std::fs::remove_file(path).ok();
        Ok(())
    });
}
