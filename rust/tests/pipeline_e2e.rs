//! End-to-end pipeline test: the whole QLESS loop on a miniature workload
//! (tiny model, small corpus, short training) with the paper's qualitative
//! claims asserted at the end.
//!
//! Requires built artifacts; skips gracefully otherwise. This is the
//! slowest test in the suite (~1–2 min) — it exercises every stage the way
//! `examples/full_pipeline.rs` does, with assertions instead of prose.

use std::path::PathBuf;

use qless::config::Config;
use qless::pipeline::{Method, Pipeline};
use qless::quant::{Precision, Scheme};

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.json").exists().then_some(p)
}

#[test]
fn qless_beats_random_and_matches_less() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut cfg = Config::default();
    cfg.model = "tiny".into();
    cfg.artifacts = dir.to_str().unwrap().into();
    cfg.run_dir = std::env::temp_dir()
        .join(format!("qless_e2e_{}", std::process::id()))
        .to_str()
        .unwrap()
        .into();
    cfg.corpus_size = 800;
    cfg.warmup_epochs = 2;
    cfg.finetune_epochs = 3;
    cfg.val_per_task = 12;
    cfg.eval_per_task = 32;
    cfg.select_frac = 0.05;
    let mut pipe = Pipeline::new(cfg).unwrap();

    let rand5 = pipe.run_method(Method::RandomFrac).unwrap();
    let less16 = pipe.run_method(Method::Qless(Precision::new(16, Scheme::Absmax).unwrap())).unwrap();
    let qless1 = pipe.run_method(Method::Qless(Precision::new(1, Scheme::Sign).unwrap())).unwrap();

    eprintln!(
        "rand5 {:.3}  less16 {:.3}  qless1 {:.3}",
        rand5.average, less16.average, qless1.average
    );

    // structural guarantees
    assert_eq!(rand5.scores.len(), 3);
    for r in [&rand5, &less16, &qless1] {
        for (&b, &s) in &r.scores {
            assert!((0.0..=1.0).contains(&s), "{b}: {s}");
        }
    }
    // storage: exactly the paper's 16x ratio (modulo fixed per-file overhead)
    assert!(less16.storage_bytes > 14 * qless1.storage_bytes);
    assert!(less16.storage_bytes <= 16 * qless1.storage_bytes);

    // The paper's qualitative ordering, with WIDE slack: at this miniature
    // scale (32 eval tasks/benchmark, 40-sample selections) one flipped
    // task moves an average by ~1pt, so score comparisons here only guard
    // against gross regressions. The statistically meaningful ordering
    // check runs at table1 scale (corpus 2000+, 96 tasks) — see
    // EXPERIMENTS.md Table 1, where every LESS/QLESS variant beats the
    // random baselines.
    // (a) targeted selection must not collapse far below random 5%
    assert!(
        qless1.average >= rand5.average - 0.08,
        "QLESS 1-bit ({:.3}) collapsed vs random 5% ({:.3})",
        qless1.average,
        rand5.average
    );
    // (b) 1-bit ≈ 16-bit (within a few points)
    assert!(
        (qless1.average - less16.average).abs() < 0.10,
        "QLESS 1-bit ({:.3}) should track LESS 16-bit ({:.3})",
        qless1.average,
        less16.average
    );

    // Fig. 5 mechanism: per-benchmark selections over-represent aligned
    // sources vs the corpus mix for at least 2 of 3 benchmarks at 16-bit.
    let mut aligned_hits = 0;
    for bench in qless::eval::Benchmark::ALL {
        let d = &less16.distributions[bench.name()];
        let base_rate = match bench.aligned_source() {
            qless::corpus::Source::SynFlan | qless::corpus::Source::SynCot => 0.372,
            qless::corpus::Source::SynDolly => 0.056,
            qless::corpus::Source::SynOasst => 0.204,
        };
        if d.frac(bench.aligned_source()) > base_rate {
            aligned_hits += 1;
        }
    }
    assert!(aligned_hits >= 2, "selection alignment too weak: {aligned_hits}/3");

    std::fs::remove_dir_all(pipe.run_dir()).ok();
}
