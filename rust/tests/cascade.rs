//! Compute-constrained precision cascade acceptance suite — the
//! cascade tentpole's contract:
//!
//! * **full-pool cascade == exhaustive scan**: with `mult · k ≥ n` the
//!   cascade's per-task top list is **byte-identical** (indices and f32
//!   score bits) to the exhaustive rerank-precision scan, across
//!   bitwidth × scheme × shard size × live generations;
//! * **recall@k is monotone** non-decreasing in the candidate
//!   multiplier, reaching exactly 1.0 once the pool covers the store;
//! * **serving is the library**: `score_cascade` answers from a server
//!   (under concurrent clients) and from a scatter-gather coordinator
//!   (1..=3 workers) are bit-identical to a direct library cascade;
//! * **paper-scale tradeoff**: at n=2048 × k=512 the 1→8-bit cascade at
//!   the default multiplier reads ≥ 2× fewer bytes than the exhaustive
//!   8-bit scan while keeping recall@k ≥ 0.95;
//! * **negative paths fail clean**: malformed `cascade` wire fields,
//!   stage verbs missing their operands, and cascades naming a precision
//!   the run directory lacks all produce errors — never a silently
//!   exhaustive or truncated answer;
//! * **observability is bookkeeping, not a second measurement**: the
//!   metrics registry's per-bitwidth scan counters equal the summed
//!   `ScanStats` of the scans run under it exactly, and malformed
//!   `trace` / `metrics` wire fields fail clean without poisoning the
//!   connection.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use qless::datastore::{default_store_path, LiveStore, SegmentWriter};
use qless::grads::FeatureMatrix;
use qless::influence::cascade::exhaustive_scan_bytes;
use qless::influence::{cascade_live_tasks, score_live_tasks, CascadeOpts, ScoreOpts};
use qless::prop_assert;
use qless::quant::{Precision, Scheme};
use qless::select::top_k_scored;
use qless::service::{Client, Coordinator, CoordinatorOpts, ServeOpts, Server};
use qless::util::obs::{self, Registry};
use qless::util::prop::{normal_features, run_prop, seeded_datastore};

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "qless_cascade_{tag}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Build the cascade's sibling pair (probe + rerank stores) for rows
/// `0..n0` from the canonical seeded feature stream.
fn build_pair(dir: &Path, probe: Precision, rerank: Precision, n0: usize, k: usize, etas: &[f32], seed: u64) {
    seeded_datastore(&default_store_path(dir, probe), probe, n0, k, etas, seed);
    seeded_datastore(&default_store_path(dir, rerank), rerank, n0, k, etas, seed);
}

/// Ingest rows `lo..hi` of the canonical stream as one generation across
/// both precisions (the manifest is shared, so the pair must ingest
/// together — exactly what `qless ingest --bits probe,rerank` does).
fn ingest_range(dir: &Path, pair: &[Precision], lo: usize, hi: usize, n_total: usize, k: usize, ckpts: usize, seed: u64) {
    let mut sw = SegmentWriter::create(dir, pair, hi - lo, 0).unwrap();
    for ci in 0..ckpts {
        sw.begin_checkpoint().unwrap();
        let f = normal_features(n_total, k, seed + ci as u64);
        sw.append_rows(&f.data[lo * k..hi * k]).unwrap();
        sw.end_checkpoint().unwrap();
    }
    sw.finalize().unwrap();
}

/// One validation task: per-checkpoint feature rows.
fn task(ckpts: usize, rows: usize, k: usize, seed: u64) -> Vec<FeatureMatrix> {
    (0..ckpts).map(|c| normal_features(rows, k, seed + 100 * c as u64)).collect()
}

/// Assert two top lists are byte-identical: same rows, same f32 bits.
fn assert_tops_identical(got: &[(usize, f32)], want: &[(usize, f32)], ctx: &str) -> Result<(), String> {
    if got.len() != want.len() {
        return Err(format!("{ctx}: {} vs {} entries", got.len(), want.len()));
    }
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        if g.0 != w.0 || g.1.to_bits() != w.1.to_bits() {
            return Err(format!("{ctx}: entry {i}: got ({}, {:x}), want ({}, {:x})", g.0, g.1.to_bits(), w.0, w.1.to_bits()));
        }
    }
    Ok(())
}

/// Recall@k of a cascade top list against the exhaustive top list.
fn recall(got: &[(usize, f32)], want: &[(usize, f32)]) -> f64 {
    let want_idx: std::collections::BTreeSet<usize> = want.iter().map(|(i, _)| *i).collect();
    let hit = got.iter().filter(|(i, _)| want_idx.contains(i)).count();
    hit as f64 / want.len().max(1) as f64
}

/// The CI smoke: a 1→8-bit cascade with a full candidate pool produces a
/// digest (rows + score bits) identical to the exhaustive 8-bit scan.
/// (`cargo test --test cascade smoke` runs exactly this.)
#[test]
fn smoke_cascade_equals_exhaustive_digest() {
    let dir = tmpdir("smoke");
    let (n, k) = (33usize, 64usize);
    let etas = [0.7f32, 0.3];
    let p1 = Precision::new(1, Scheme::Sign).unwrap();
    let p8 = Precision::new(8, Scheme::Absmax).unwrap();
    build_pair(&dir, p1, p8, n, k, &etas, 1);
    let probe = LiveStore::open(&default_store_path(&dir, p1)).unwrap();
    let rerank = LiveStore::open(&default_store_path(&dir, p8)).unwrap();
    let t0 = task(2, 2, k, 500);
    let t1 = task(2, 3, k, 600);
    let tasks: Vec<&[FeatureMatrix]> = vec![&t0, &t1];
    // mult 7 · k 5 = 35 ≥ 33 rows → the candidate pool covers the store
    let opts = CascadeOpts { k: 5, mult: 7, scan: ScoreOpts { shard_rows: 6, ..Default::default() } };
    let out = cascade_live_tasks(&probe, &rerank, &tasks, opts).unwrap();
    assert_eq!(out.reranked_rows, n, "full pool reranks every row");
    let (scores, _) = score_live_tasks(&rerank, &tasks, opts.scan).unwrap();
    for (t, top) in out.top.iter().enumerate() {
        let want = top_k_scored(&scores[t], 5);
        let digest_got: Vec<(usize, u32)> = top.iter().map(|(i, s)| (*i, s.to_bits())).collect();
        let digest_want: Vec<(usize, u32)> = want.iter().map(|(i, s)| (*i, s.to_bits())).collect();
        assert_eq!(digest_got, digest_want, "task {t}: cascade digest != exhaustive digest");
    }
    // the probe pass walked every row once per checkpoint
    assert_eq!(out.probe_pass.rows_read, (2 * n) as u64);
    assert_eq!(out.rerank_pass.rows_read, (2 * n) as u64);
    std::fs::remove_dir_all(&dir).ok();
}

/// Property: across rerank bitwidth × scheme × shard size × live
/// generations × task count, a cascade whose candidate pool covers the
/// store is byte-identical to the exhaustive rerank-precision scan.
#[test]
fn prop_full_pool_cascade_is_byte_identical_to_exhaustive() {
    let rerank_grid = [
        Precision::new(16, Scheme::Absmax).unwrap(),
        Precision::new(8, Scheme::Absmax).unwrap(),
        Precision::new(8, Scheme::Absmean).unwrap(),
        Precision::new(4, Scheme::Absmax).unwrap(),
        Precision::new(4, Scheme::Absmean).unwrap(),
        Precision::new(2, Scheme::Absmean).unwrap(),
    ];
    run_prop("cascade-exhaustive", 12, |g| {
        let n0 = 3 + g.usize_up_to(14);
        let add1 = g.rng.below(8);
        let add2 = if add1 > 0 { g.rng.below(5) } else { 0 };
        let n = n0 + add1 + add2;
        // k deliberately NOT a multiple of 8 half the time (packed rows
        // that end mid-byte)
        let k = 5 + g.usize_up_to(60);
        let ckpts = 1 + g.rng.below(2);
        let etas: Vec<f32> = (0..ckpts).map(|c| 0.9 - 0.4 * c as f32).collect();
        let seed = g.rng.below(1 << 20) as u64;
        let probe = Precision::new(1, Scheme::Sign).unwrap();
        let rerank = rerank_grid[g.rng.below(rerank_grid.len())];
        let dir = tmpdir("prop");
        build_pair(&dir, probe, rerank, n0, k, &etas, seed);
        if add1 > 0 {
            ingest_range(&dir, &[probe, rerank], n0, n0 + add1, n, k, ckpts, seed);
        }
        if add2 > 0 {
            ingest_range(&dir, &[probe, rerank], n0 + add1, n, k, ckpts, seed);
        }
        let probe_live = LiveStore::open(&default_store_path(&dir, probe)).unwrap();
        let rerank_live = LiveStore::open(&default_store_path(&dir, rerank)).unwrap();
        let held: Vec<Vec<FeatureMatrix>> =
            (0..1 + g.rng.below(3)).map(|q| task(ckpts, 1 + g.rng.below(3), k, 7000 + 31 * q as u64)).collect();
        let tasks: Vec<&[FeatureMatrix]> = held.iter().map(|t| t.as_slice()).collect();
        let k_sel = 1 + g.rng.below(n);
        // enough candidates to cover the store, plus arbitrary slack
        let mult = n.div_ceil(k_sel) + g.rng.below(3);
        let opts = CascadeOpts {
            k: k_sel,
            mult,
            scan: ScoreOpts { shard_rows: 1 + g.rng.below(n + 2), ..Default::default() },
        };
        let out = cascade_live_tasks(&probe_live, &rerank_live, &tasks, opts)
            .map_err(|e| format!("cascade failed: {e:#}"))?;
        prop_assert!(out.reranked_rows == n, "full pool must rerank all {n} rows (got {})", out.reranked_rows);
        let (scores, _) = score_live_tasks(&rerank_live, &tasks, opts.scan).unwrap();
        for (t, top) in out.top.iter().enumerate() {
            let want = top_k_scored(&scores[t], k_sel);
            assert_tops_identical(
                top,
                &want,
                &format!(
                    "task {t} ({} rerank, n0={n0} add1={add1} add2={add2} k={k} k_sel={k_sel} \
                     mult={mult} shard_rows={})",
                    rerank.label(),
                    opts.scan.shard_rows
                ),
            )?;
        }
        std::fs::remove_dir_all(&dir).ok();
        Ok(())
    });
}

/// Property: recall@k against the exhaustive top list never decreases as
/// the candidate multiplier grows, and is exactly 1.0 once
/// `mult · k ≥ n`. (A smaller pool is a subset of a bigger one, and any
/// exhaustive winner inside a pool survives its rerank — so the set of
/// recovered winners can only grow.)
#[test]
fn prop_recall_is_monotone_in_the_candidate_multiplier() {
    run_prop("cascade-recall-monotone", 10, |g| {
        let n = 16 + g.usize_up_to(40);
        let k = 8 + g.usize_up_to(56);
        let ckpts = 1 + g.rng.below(2);
        let etas: Vec<f32> = (0..ckpts).map(|c| 0.8 - 0.3 * c as f32).collect();
        let seed = g.rng.below(1 << 20) as u64;
        let p1 = Precision::new(1, Scheme::Sign).unwrap();
        let p8 = Precision::new(8, Scheme::Absmax).unwrap();
        let dir = tmpdir("mono");
        build_pair(&dir, p1, p8, n, k, &etas, seed);
        let probe_live = LiveStore::open(&default_store_path(&dir, p1)).unwrap();
        let rerank_live = LiveStore::open(&default_store_path(&dir, p8)).unwrap();
        let t0 = task(ckpts, 2, k, 9000);
        let tasks: Vec<&[FeatureMatrix]> = vec![&t0];
        let k_sel = 1 + g.rng.below(6);
        let scan = ScoreOpts { shard_rows: 1 + g.rng.below(n), ..Default::default() };
        let (scores, _) = score_live_tasks(&rerank_live, &tasks, scan).unwrap();
        let want = top_k_scored(&scores[0], k_sel);
        let mut prev = -1.0f64;
        let mut mult = 1usize;
        loop {
            let out =
                cascade_live_tasks(&probe_live, &rerank_live, &tasks, CascadeOpts { k: k_sel, mult, scan })
                    .map_err(|e| format!("cascade failed: {e:#}"))?;
            let r = recall(&out.top[0], &want);
            prop_assert!(
                r >= prev,
                "recall fell from {prev:.3} to {r:.3} when mult grew to {mult} \
                 (n={n} k={k} k_sel={k_sel})"
            );
            prev = r;
            if mult * k_sel >= n {
                prop_assert!(r == 1.0, "full pool (mult={mult}) must have recall 1.0, got {r:.3}");
                assert_tops_identical(&out.top[0], &want, "full-pool top list")?;
                break;
            }
            mult *= 2;
        }
        std::fs::remove_dir_all(&dir).ok();
        Ok(())
    });
}

/// Paper-scale tradeoff at the default multiplier: the 1→8-bit cascade
/// must read at least 2× fewer bytes than the exhaustive 8-bit scan and
/// keep recall@k ≥ 0.95 — the PR's acceptance numbers, also logged by
/// `qless xp cascade` and `bench_influence`.
#[test]
fn cascade_halves_io_at_paper_scale_with_high_recall() {
    let dir = tmpdir("paper");
    let (n, k, k_sel) = (2048usize, 512usize, 32usize);
    let etas = [0.6f32, 0.4];
    let p1 = Precision::new(1, Scheme::Sign).unwrap();
    let p8 = Precision::new(8, Scheme::Absmax).unwrap();
    build_pair(&dir, p1, p8, n, k, &etas, 42);
    let probe_live = LiveStore::open(&default_store_path(&dir, p1)).unwrap();
    let rerank_live = LiveStore::open(&default_store_path(&dir, p8)).unwrap();
    let t0 = task(2, 4, k, 1234);
    let t1 = task(2, 4, k, 5678);
    let tasks: Vec<&[FeatureMatrix]> = vec![&t0, &t1];
    let opts = CascadeOpts {
        k: k_sel,
        mult: qless::influence::DEFAULT_CASCADE_MULT,
        scan: ScoreOpts { shard_rows: 256, ..Default::default() },
    };
    let out = cascade_live_tasks(&probe_live, &rerank_live, &tasks, opts).unwrap();
    let exhaustive = exhaustive_scan_bytes(rerank_live.header(), n);
    let read = out.combined_pass().bytes_read;
    assert!(
        read * 2 <= exhaustive,
        "cascade read {read} B, exhaustive {exhaustive} B — less than 2× reduction"
    );
    let (scores, _) = score_live_tasks(&rerank_live, &tasks, opts.scan).unwrap();
    for (t, top) in out.top.iter().enumerate() {
        let want = top_k_scored(&scores[t], k_sel);
        let r = recall(top, &want);
        assert!(r >= 0.95, "task {t}: recall@{k_sel} = {r:.3} < 0.95 at the default multiplier");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Served cascades are the library cascade: answers from a single server
/// under concurrent clients and from scatter-gather coordinators with
/// 1..=3 workers are all bit-identical to `cascade_live_tasks`. (For a
/// single-task query the scattered candidate pool — merged per-slice
/// probe tops — equals the global probe top-`c·k`, so the equivalence is
/// exact at ANY multiplier, not only exhaustive ones.)
#[test]
fn served_cascades_match_the_library_under_concurrency_and_scatter() {
    let dir = tmpdir("serve");
    let (n, k) = (41usize, 64usize);
    let etas = [0.6f32, 0.4];
    let p1 = Precision::new(1, Scheme::Sign).unwrap();
    let p8 = Precision::new(8, Scheme::Absmax).unwrap();
    build_pair(&dir, p1, p8, n, k, &etas, 3);
    let probe_path = default_store_path(&dir, p1);
    let probe_live = LiveStore::open(&probe_path).unwrap();
    let rerank_live = LiveStore::open(&default_store_path(&dir, p8)).unwrap();
    let held: Vec<Vec<FeatureMatrix>> = (0..3).map(|q| task(2, 2, k, 4000 + 17 * q)).collect();
    let tasks: Vec<&[FeatureMatrix]> = held.iter().map(|t| t.as_slice()).collect();
    let opts = CascadeOpts { k: 4, mult: 2, scan: ScoreOpts { shard_rows: 7, ..Default::default() } };
    let want = cascade_live_tasks(&probe_live, &rerank_live, &tasks, opts).unwrap().top;
    let serve_opts = ServeOpts {
        addr: "127.0.0.1:0".into(),
        batch_window_ms: 5,
        shard_rows: 7,
        ..Default::default()
    };
    // single server, three concurrent cascade clients
    let server = Server::start(&probe_path, serve_opts.clone()).unwrap();
    let addr = server.addr();
    std::thread::scope(|s| {
        for (t, val) in held.iter().enumerate() {
            let want_t = &want[t];
            s.spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let r = c.score_cascade(val, 4, 1, 8, 2).unwrap();
                let got: Vec<(usize, u32)> = r.top.iter().map(|(i, s)| (*i, s.to_bits())).collect();
                let exp: Vec<(usize, u32)> = want_t.iter().map(|(i, s)| (*i, s.to_bits())).collect();
                assert_eq!(got, exp, "task {t}: served cascade != library cascade");
            });
        }
    });
    server.stop();
    server.join().unwrap();
    // scatter-gather: 1, 2 and 3 workers all merge to the same answer
    for workers in 1..=3usize {
        let co = Coordinator::start_local(
            &probe_path,
            workers,
            serve_opts.clone(),
            CoordinatorOpts { addr: "127.0.0.1:0".into(), ..Default::default() },
        )
        .unwrap();
        let mut c = Client::connect(co.addr()).unwrap();
        for (t, val) in held.iter().enumerate() {
            let r = c.score_cascade(val, 4, 1, 8, 2).unwrap();
            let got: Vec<(usize, u32)> = r.top.iter().map(|(i, s)| (*i, s.to_bits())).collect();
            let exp: Vec<(usize, u32)> = want[t].iter().map(|(i, s)| (*i, s.to_bits())).collect();
            assert_eq!(got, exp, "{workers} workers, task {t}: scattered cascade != library");
        }
        c.shutdown().unwrap();
        co.join().unwrap();
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Negative paths over the wire: malformed `cascade` fields and
/// unsatisfiable cascades are clean errors that leave the connection
/// usable — never a silently exhaustive or truncated answer.
#[test]
fn malformed_and_unsatisfiable_cascades_fail_clean_over_the_wire() {
    let dir = tmpdir("neg");
    let (n, k) = (9usize, 64usize);
    let p8 = Precision::new(8, Scheme::Absmax).unwrap();
    // a SINGLE-precision run: only the 8-bit store exists
    seeded_datastore(&default_store_path(&dir, p8), p8, n, k, &[1.0], 0);
    let server = Server::start(
        &default_store_path(&dir, p8),
        ServeOpts { addr: "127.0.0.1:0".into(), batch_window_ms: 0, ..Default::default() },
    )
    .unwrap();
    let mut c = Client::connect(server.addr()).unwrap();
    let val = task(1, 2, k, 77);
    // probe precision absent from the run dir → the error names the
    // missing store and the fix, and nothing is scored
    let err = format!("{:#}", c.score_cascade(&val, 2, 1, 8, 4).unwrap_err());
    assert!(err.contains("no 1-bit store"), "{err}");
    assert!(err.contains("--bits"), "{err}");
    // malformed cascade fields → parse/validation errors with the exact
    // complaint; the connection survives every one
    let zeros = vec!["0"; k].join(",");
    let line = |cascade: &str, extra: &str| {
        format!(
            "{{\"op\":\"score\",\"id\":7,\"top_k\":2,{extra}\"cascade\":{cascade},\
             \"val\":[{{\"n\":1,\"k\":{k},\"data\":[{zeros}]}}]}}"
        )
    };
    let cases: &[(&str, &str, &str)] = &[
        ("5", "", "must be an object"),
        ("{\"probe\":1}", "", "missing key 'rerank'"),
        ("{\"probe\":3,\"rerank\":8}", "", "one of 1,2,4,8,16"),
        ("{\"probe\":8,\"rerank\":1}", "", "below rerank"),
        ("{\"probe\":1,\"rerank\":8,\"mult\":0}", "", "'mult' must be >= 1"),
        ("{\"probe\":1,\"rerank\":8,\"multt\":2}", "", "unknown key 'multt'"),
        ("{\"stage\":\"probe\",\"probe\":1,\"rows_list\":[1]}", "", "unknown key 'rows_list'"),
        ("{\"stage\":\"rerank\",\"rerank\":8,\"rows_list\":[]}", "", "at least one row"),
        ("{\"stage\":\"rerank\",\"rerank\":8,\"rows_list\":[3,1]}", "", "strictly increasing"),
        ("{\"stage\":\"shrink\"}", "", "unknown cascade stage"),
        // well-formed cascade, unsatisfiable combination
        ("{\"probe\":1,\"rerank\":8}", "\"scores\":true,", "drop 'want_scores'"),
        ("{\"probe\":1,\"rerank\":8}", "\"since_gen\":0,", "since_gen"),
        ("{\"probe\":1,\"rerank\":8}", "\"rows\":[0,4],", "stage verbs"),
        ("{\"stage\":\"probe\",\"probe\":8}", "", "must carry a 'rows' range"),
    ];
    for (cascade, extra, msg) in cases {
        let raw = c.raw_roundtrip(&line(cascade, extra)).unwrap();
        assert!(raw.contains("\"ok\":false"), "cascade {cascade} answered: {raw}");
        assert!(raw.contains(msg), "cascade {cascade}: expected {msg:?} in {raw}");
        c.ping().unwrap();
    }
    // rerank rows beyond the live row count → clean error, no partial top
    let raw = c
        .raw_roundtrip(&line("{\"stage\":\"rerank\",\"rerank\":8,\"rows_list\":[100]}", ""))
        .unwrap();
    assert!(raw.contains("\"ok\":false"), "{raw}");
    assert!(raw.contains("exceeds live rows"), "{raw}");
    c.ping().unwrap();
    // top_k 0 on a full cascade → clean error
    let raw = c
        .raw_roundtrip(&line("{\"probe\":1,\"rerank\":8}", "").replace("\"top_k\":2", "\"top_k\":0"))
        .unwrap();
    assert!(raw.contains("top_k >= 1"), "{raw}");
    c.shutdown().unwrap();
    server.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// observability
// ---------------------------------------------------------------------------

/// Property: the observability registry's per-bitwidth scan counters are
/// EXACTLY the summed `ScanStats` of the scans run under it — exhaustive
/// and cascade, across the bitwidth × scheme grid and live generations.
/// (Ranged scans go through the same `MultiScan` seam: an exhaustive
/// scan IS the full-row ranged scan.) The registry is bookkeeping over
/// the same measurements the passes already make, never a second,
/// drifting measurement — hence exact equality, not `>=`.
#[test]
fn prop_registry_scan_counters_equal_summed_scan_stats() {
    let rerank_grid = [
        Precision::new(16, Scheme::Absmax).unwrap(),
        Precision::new(8, Scheme::Absmax).unwrap(),
        Precision::new(8, Scheme::Absmean).unwrap(),
        Precision::new(4, Scheme::Absmax).unwrap(),
        Precision::new(4, Scheme::Absmean).unwrap(),
        Precision::new(2, Scheme::Absmean).unwrap(),
    ];
    run_prop("obs-scan-counters-exact", 8, |g| {
        let n0 = 4 + g.usize_up_to(12);
        let add = g.rng.below(6);
        let n = n0 + add;
        let k = 6 + g.usize_up_to(40);
        let ckpts = 1 + g.rng.below(2);
        let etas: Vec<f32> = (0..ckpts).map(|c| 0.9 - 0.4 * c as f32).collect();
        let seed = g.rng.below(1 << 20) as u64;
        let probe = Precision::new(1, Scheme::Sign).unwrap();
        let rerank = rerank_grid[g.rng.below(rerank_grid.len())];
        let dir = tmpdir("obsprop");
        build_pair(&dir, probe, rerank, n0, k, &etas, seed);
        if add > 0 {
            ingest_range(&dir, &[probe, rerank], n0, n, n, k, ckpts, seed);
        }
        let probe_live = LiveStore::open(&default_store_path(&dir, probe)).unwrap();
        let rerank_live = LiveStore::open(&default_store_path(&dir, rerank)).unwrap();
        let t0 = task(ckpts, 2, k, 321);
        let tasks: Vec<&[FeatureMatrix]> = vec![&t0];
        let scan = ScoreOpts { shard_rows: 1 + g.rng.below(n + 2), ..Default::default() };

        // an instantiable registry scoped to this thread: only THESE two
        // scans feed it, no matter what parallel tests do to the global
        let reg = Arc::new(Registry::new());
        let (exhaustive, out) = obs::with_registry(reg.clone(), || {
            let (_, s) = score_live_tasks(&rerank_live, &tasks, scan).unwrap();
            let out = cascade_live_tasks(
                &probe_live,
                &rerank_live,
                &tasks,
                CascadeOpts { k: 1 + g.rng.below(n), mult: 1 + g.rng.below(3), scan },
            )
            .unwrap();
            (s, out)
        });
        let snap = reg.snapshot();
        let counter = |name: &str, bits: u8| {
            snap.counters.get(&format!("{name}{{bits=\"{bits}\"}}")).copied().unwrap_or(0)
        };
        // the probe bitwidth saw exactly the cascade's probe pass
        prop_assert!(
            counter("scan_rows_total", probe.bits) == out.probe_pass.rows_read,
            "probe rows: counter {} != ScanStats {} ({} rerank, n={n} k={k})",
            counter("scan_rows_total", probe.bits),
            out.probe_pass.rows_read,
            rerank.label()
        );
        prop_assert!(
            counter("scan_bytes_total", probe.bits) == out.probe_pass.bytes_read,
            "probe bytes: counter {} != ScanStats {}",
            counter("scan_bytes_total", probe.bits),
            out.probe_pass.bytes_read
        );
        // the rerank bitwidth saw the exhaustive scan plus the rerank pass
        let want_rows = exhaustive.rows_read + out.rerank_pass.rows_read;
        let want_bytes = exhaustive.bytes_read + out.rerank_pass.bytes_read;
        prop_assert!(
            counter("scan_rows_total", rerank.bits) == want_rows,
            "rerank rows: counter {} != summed ScanStats {want_rows} ({} rerank)",
            counter("scan_rows_total", rerank.bits),
            rerank.label()
        );
        prop_assert!(
            counter("scan_bytes_total", rerank.bits) == want_bytes,
            "rerank bytes: counter {} != summed ScanStats {want_bytes}",
            counter("scan_bytes_total", rerank.bits)
        );
        prop_assert!(
            counter("scan_passes_total", probe.bits) >= 1
                && counter("scan_passes_total", rerank.bits) >= 2,
            "pass counters must tick once per finished scan"
        );
        std::fs::remove_dir_all(&dir).ok();
        Ok(())
    });
}

/// Negative paths for the observability surface: malformed `trace`
/// fields and unknown `metrics` keys are clean errors that leave the
/// connection usable — and after every rejection the happy path still
/// works, traced timing and Prometheus text included.
#[test]
fn malformed_trace_and_metrics_fields_fail_clean_over_the_wire() {
    let dir = tmpdir("obsneg");
    let (n, k) = (7usize, 64usize);
    let p8 = Precision::new(8, Scheme::Absmax).unwrap();
    seeded_datastore(&default_store_path(&dir, p8), p8, n, k, &[1.0], 5);
    let server = Server::start(
        &default_store_path(&dir, p8),
        ServeOpts { addr: "127.0.0.1:0".into(), batch_window_ms: 0, ..Default::default() },
    )
    .unwrap();
    let mut c = Client::connect(server.addr()).unwrap();
    let zeros = vec!["0"; k].join(",");
    let score_line = |trace: &str| {
        format!(
            "{{\"op\":\"score\",\"id\":3,\"top_k\":2,\"trace\":{trace},\
             \"val\":[{{\"n\":1,\"k\":{k},\"data\":[{zeros}]}}]}}"
        )
    };
    let cases: &[(&str, &str)] = &[
        ("7", "'trace' must be an object"),
        ("[\"0x1\"]", "'trace' must be an object"),
        ("{}", "malformed 'trace' id"),
        ("{\"id\":\"0xzz\"}", "malformed 'trace' id"),
        ("{\"id\":\"0x0\"}", "'trace' id must be nonzero"),
        ("{\"id\":\"0x2a\",\"parrent\":\"0x1\"}", "unknown key 'parrent' in 'trace'"),
        ("{\"id\":\"0x2a\",\"parent\":\"frogs\"}", "malformed 'trace' parent"),
    ];
    for (trace, msg) in cases {
        let raw = c.raw_roundtrip(&score_line(trace)).unwrap();
        assert!(raw.contains("\"ok\":false"), "trace {trace} answered: {raw}");
        assert!(raw.contains(msg), "trace {trace}: expected {msg:?} in {raw}");
        c.ping().unwrap();
    }
    let mcases: &[(&str, &str)] = &[
        ("{\"op\":\"metrics\",\"id\":4,\"bogus\":1}", "unknown key 'bogus' in 'metrics' request"),
        ("{\"op\":\"metrics\",\"id\":4,\"traces\":1}", "'traces' must be a bool"),
        ("{\"op\":\"metrics\",\"id\":4,\"prometheus\":\"yes\"}", "'prometheus' must be a bool"),
    ];
    for (line, msg) in mcases {
        let raw = c.raw_roundtrip(line).unwrap();
        assert!(raw.contains("\"ok\":false"), "{line} answered: {raw}");
        assert!(raw.contains(msg), "{line}: expected {msg:?} in {raw}");
        c.ping().unwrap();
    }
    // after every rejection the connection still serves the happy path:
    // a well-formed traced score answers WITH its timing spans...
    let raw = c.raw_roundtrip(&score_line("{\"id\":\"0xbeef\"}")).unwrap();
    assert!(raw.contains("\"timing\""), "traced score must carry timing: {raw}");
    assert!(raw.contains("server.score"), "{raw}");
    // ...and a well-formed metrics scrape answers with Prometheus text
    let m = c.metrics(false, true).unwrap();
    assert!(m.prometheus.unwrap().contains("qless_"), "prometheus text renders");
    c.shutdown().unwrap();
    server.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}
