//! Kernel-dispatch equality suite (PR 9's contract): every kernel
//! variant this machine supports — blocked scalar, AVX2, NEON — must be
//! **bit-exact** against the pinned scalar reference on the 1-bit and
//! integer-domain paths, across bitwidth × scheme × k (mid-byte tails and
//! k > 4096 included), fused multi-query shapes, and arbitrary view
//! splits. The f32-accumulated dense path keeps its ≤1e-5 bound.
//!
//! CI runs this file twice: once with `QLESS_KERNEL=scalar` forced (the
//! reference must agree with itself and dispatch must honor the
//! override), once under native dispatch — a broken SIMD path can never
//! pass green by accident.
//!
//! Also here: the `int_dot_fits` i32-overflow boundary (exact bound and
//! one past it, per bitwidth; the scan dispatch must fall back to the f32
//! path rather than overflow) and the dispatch observability seams
//! (per-variant scan-row counters, `kernel_dispatch` gauge).

use std::path::PathBuf;
use std::sync::Arc;

use qless::datastore::CheckpointBlock;
use qless::influence::native::{
    int_dot_fits, scores_dense_rows, scores_int_rows, scores_rows, scores_rows_with, tile_rows,
    ValFeatures,
};
use qless::prop_assert;
use qless::quant::{Precision, Scheme};
use qless::util::cpu::{self, Kernel};
use qless::util::obs::{self, Registry};
use qless::util::prop::{normal_features as feats, run_prop, seeded_datastore};

fn tmpfile(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "qless_kern_{tag}_{}_{:?}.qlds",
        std::process::id(),
        std::thread::current().id()
    ))
}

/// Build a one-checkpoint store and return its loaded block.
fn block(tag: &str, p: Precision, n: usize, k: usize, seed: u64) -> CheckpointBlock {
    let path = tmpfile(tag);
    let ds = seeded_datastore(&path, p, n, k, &[1.0], seed);
    let b = ds.load_checkpoint(0).unwrap();
    std::fs::remove_file(&path).ok();
    b
}

fn assert_bitwise(reference: &[f32], got: &[f32], ctx: &str) {
    assert_eq!(reference.len(), got.len(), "{ctx}: length");
    for (i, (a, b)) in reference.iter().zip(got).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{ctx} idx {i}: {a} vs {b}");
    }
}

#[test]
fn prop_kernel_variants_bit_exact_across_bitwidth_scheme_k() {
    // The tentpole property: scalar vs blocked vs SIMD, bit-for-bit, on
    // every exact path. k list hits mid-byte packed tails at every
    // bitwidth (k·bits % 8 ≠ 0) and the >4096 regime where a tile holds
    // only the clamp-floor 4 rows.
    let combos: [(u8, Scheme); 7] = [
        (1, Scheme::Sign),
        (2, Scheme::Absmax),
        (2, Scheme::Absmean),
        (4, Scheme::Absmax),
        (4, Scheme::Absmean),
        (8, Scheme::Absmax),
        (8, Scheme::Absmean),
    ];
    run_prop("kernel-bit-exact", 12, |g| {
        let n = 5 + g.usize_up_to(60);
        let k = [64usize, 65, 97, 127, 513, 4099][g.rng.below(6)];
        let q = 1 + g.rng.below(3);
        let seed = g.rng.below(1 << 20) as u64;
        for (bits, scheme) in combos {
            let p = Precision::new(bits, scheme).unwrap();
            let b = block(&format!("grid{bits}{scheme}"), p, n, k, seed);
            let tasks: Vec<_> = (0..q).map(|t| feats(1 + t, k, seed + 100 + t as u64)).collect();
            let refs: Vec<&_> = tasks.iter().collect();
            let val = ValFeatures::try_prepare_tasks(&refs, p).unwrap();
            let rows = b.rows();
            let reference = scores_rows_with(&rows, &val, Kernel::Scalar);
            prop_assert!(reference.len() == n * q, "shape {bits}-bit");
            // dense reference sanity: the exact kernels track f32 ≤ 1e-5
            let dense = scores_dense_rows(&rows, &val);
            for (i, (a, d)) in reference.iter().zip(&dense).enumerate() {
                prop_assert!(
                    (a - d).abs() < 1e-5,
                    "{bits}-bit {scheme} k={k} idx {i}: scalar {a} vs dense {d}"
                );
            }
            for kernel in cpu::available() {
                let got = scores_rows_with(&rows, &val, kernel);
                for (i, (a, b)) in reference.iter().zip(&got).enumerate() {
                    prop_assert!(
                        a.to_bits() == b.to_bits(),
                        "{bits}-bit {scheme} k={k} n={n} q={q} kernel {} idx {i}: {a} vs {b}",
                        kernel.label()
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn kernel_variants_bit_exact_at_k8192() {
    // Paper-scale k: a 16 KiB 8-bit row pins the tile at the clamp floor
    // (tile_rows = 4), so blocks, tails and SIMD main loops all engage.
    assert_eq!(tile_rows(8192), 4);
    for (bits, scheme) in [(1u8, Scheme::Sign), (8, Scheme::Absmax)] {
        let p = Precision::new(bits, scheme).unwrap();
        let b = block(&format!("k8192_{bits}"), p, 10, 8192, 7 + bits as u64);
        let t0 = feats(3, 8192, 70);
        let t1 = feats(1, 8192, 71);
        let val = ValFeatures::try_prepare_tasks(&[&t0, &t1], p).unwrap();
        let rows = b.rows();
        let reference = scores_rows_with(&rows, &val, Kernel::Scalar);
        for kernel in cpu::available() {
            let got = scores_rows_with(&rows, &val, kernel);
            assert_bitwise(&reference, &got, &format!("{bits}-bit k=8192 {}", kernel.label()));
        }
    }
}

#[test]
fn fused_multiquery_equals_singles_for_every_kernel() {
    // One fused Q=3 traversal == three single-task runs, per variant —
    // blocking shares a tile across task columns but must not share
    // accumulation.
    let k = 130; // mid-byte tail at 1/2/4-bit
    for bits in [1u8, 2, 4, 8] {
        let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
        let p = Precision::new(bits, scheme).unwrap();
        let b = block(&format!("fused{bits}"), p, 23, k, 80 + bits as u64);
        let t0 = feats(2, k, 81);
        let t1 = feats(4, k, 82);
        let t2 = feats(1, k, 83);
        let multi = ValFeatures::try_prepare_tasks(&[&t0, &t1, &t2], p).unwrap();
        let rows = b.rows();
        for kernel in cpu::available() {
            let fused = scores_rows_with(&rows, &multi, kernel);
            for (t, feat) in [&t0, &t1, &t2].into_iter().enumerate() {
                let single = ValFeatures::try_prepare_tasks(&[feat], p).unwrap();
                let alone = scores_rows_with(&rows, &single, kernel);
                for i in 0..rows.n() {
                    assert_eq!(
                        alone[i].to_bits(),
                        fused[i * 3 + t].to_bits(),
                        "bits {bits} kernel {} task {t} row {i}",
                        kernel.label()
                    );
                }
            }
        }
    }
}

#[test]
fn view_splits_are_tile_invariant_for_every_kernel() {
    // Scoring a clipped view must be bit-identical to the same rows inside
    // the whole view, at splits that do NOT align with tile boundaries —
    // the cascade's clipped rerank feeds and the scatter workers' row
    // ranges depend on this.
    for bits in [1u8, 4, 8] {
        let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
        let p = Precision::new(bits, scheme).unwrap();
        let b = block(&format!("split{bits}"), p, 41, 257, 90 + bits as u64);
        let val = ValFeatures::try_prepare_tasks(&[&feats(3, 257, 91)], p).unwrap();
        let full = b.rows();
        for kernel in cpu::available() {
            let whole = scores_rows_with(&full, &val, kernel);
            for (a, z) in [(0usize, 7usize), (7, 41), (13, 14), (3, 38)] {
                let part = scores_rows_with(&full.slice(a, z), &val, kernel);
                assert_bitwise(
                    &whole[a..z],
                    &part,
                    &format!("bits {bits} kernel {} rows [{a},{z})", kernel.label()),
                );
            }
        }
    }
}

#[test]
fn parallel_blocked_path_matches_scalar() {
    // Enough rows × work to cross the pool's serial threshold
    // (n ≥ 256, n·nv·k ≥ 8M): the tile-granular parallel path must agree
    // with the serial scalar reference bit-for-bit.
    let (n, k) = (2048usize, 512usize);
    let p = Precision::new(8, Scheme::Absmax).unwrap();
    let b = block("par", p, n, k, 101);
    let t0 = feats(8, k, 102);
    let val = ValFeatures::try_prepare_tasks(&[&t0], p).unwrap();
    let rows = b.rows();
    let reference = scores_rows_with(&rows, &val, Kernel::Scalar);
    for kernel in cpu::available() {
        let got = scores_rows_with(&rows, &val, kernel);
        assert_bitwise(&reference, &got, &format!("parallel kernel {}", kernel.label()));
    }
}

#[test]
fn int_dot_fits_exact_overflow_boundaries() {
    // The bound is ⌊i32::MAX / (2α²)⌋ per bitwidth — exactly at fits,
    // one past does not.
    for (bits, alpha) in [(8u8, 127i64), (4, 7), (2, 1)] {
        let bound = (i32::MAX as i64 / (2 * alpha * alpha)) as usize;
        assert!(int_dot_fits(bits, bound), "{bits}-bit at bound {bound}");
        assert!(!int_dot_fits(bits, bound + 1), "{bits}-bit one past {bound}");
    }
    // the numeric bounds themselves, pinned so a refactor can't drift them
    assert!(int_dot_fits(8, 66_572) && !int_dot_fits(8, 66_573));
    assert!(int_dot_fits(4, 21_913_098) && !int_dot_fits(4, 21_913_099));
    assert!(int_dot_fits(2, 1_073_741_823) && !int_dot_fits(2, 1_073_741_824));
}

#[test]
fn f32_fallback_engages_one_past_the_8bit_bound() {
    let p = Precision::new(8, Scheme::Absmax).unwrap();
    let n = 3usize;

    // exactly at the bound: the integer engine is the dispatch target and
    // every variant still agrees with the scalar reference bitwise
    let k_at = 66_572usize;
    let b = block("bound_at", p, n, k_at, 110);
    let val = ValFeatures::try_prepare_tasks(&[&feats(1, k_at, 111)], p).unwrap();
    let rows = b.rows();
    let via_dispatch = scores_rows(&rows, &val);
    let via_int = scores_int_rows(&rows, &val);
    let active = cpu::active();
    assert_bitwise(
        &scores_rows_with(&rows, &val, active),
        &via_dispatch,
        "dispatch == active variant at the bound",
    );
    for kernel in cpu::available() {
        assert_bitwise(
            &via_int,
            &scores_rows_with(&rows, &val, kernel),
            &format!("at-bound kernel {}", kernel.label()),
        );
    }

    // one past: the integer engine must refuse (overflow hazard) and the
    // dispatch must route every variant to the identical f32 dense path
    let k_past = 66_573usize;
    let b = block("bound_past", p, n, k_past, 112);
    let val = ValFeatures::try_prepare_tasks(&[&feats(1, k_past, 113)], p).unwrap();
    let rows = b.rows();
    let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        scores_int_rows(&rows, &val)
    }));
    assert!(panicked.is_err(), "scores_int_rows must reject k past the i32 bound");
    let dense = scores_dense_rows(&rows, &val);
    assert_bitwise(&dense, &scores_rows(&rows, &val), "dispatch falls back to dense");
    for kernel in cpu::available() {
        assert_bitwise(
            &dense,
            &scores_rows_with(&rows, &val, kernel),
            &format!("past-bound kernel {} routes to dense", kernel.label()),
        );
    }
}

#[test]
fn active_kernel_is_supported_and_honors_env_override() {
    // `active()` memoizes its pick in a OnceLock, so this test must stay
    // strictly read-only: there is no `set_var` here (and must never be —
    // mutating the environment would race sibling test threads and could
    // not change an already-latched dispatch anyway). Instead we assert
    // dispatch identity against `resolve`, the exact seam `active()`
    // feeds `QLESS_KERNEL` through, under whatever value the harness
    // launched us with — this covers the CI matrix's scalar-forced leg
    // and the native auto-detect leg with one body.
    let active = cpu::active();
    assert!(active.supported(), "active() may only pick a runnable variant");
    let over = std::env::var("QLESS_KERNEL").ok();
    assert_eq!(
        active,
        cpu::resolve(over.as_deref()),
        "active() must agree with resolve({:?})",
        over
    );
    // resolve() itself can never hand back an unrunnable variant, no
    // matter what string it is fed
    for forced in ["scalar", "blocked", "avx2", "neon", "auto", "", "bogus"] {
        assert!(
            cpu::resolve(Some(forced)).supported(),
            "resolve({forced:?}) picked an unrunnable variant"
        );
    }
    // everywhere-supported forces resolve to exactly the named kernel
    assert_eq!(cpu::resolve(Some("scalar")), Kernel::Scalar);
    assert_eq!(cpu::resolve(Some("blocked")), Kernel::Blocked);
    // auto-detect (or an unsupported force falling back to it) never
    // silently picks the pinned scalar reference
    assert_ne!(cpu::resolve(None), Kernel::Scalar);
    assert_ne!(cpu::resolve(Some("bogus")), Kernel::Scalar);
}

#[test]
fn dispatch_publishes_rows_counter_and_gauge() {
    // per-variant per-bitwidth rows flow into the calling thread's
    // registry exactly once per scored row...
    let p = Precision::new(8, Scheme::Absmax).unwrap();
    let (n, k) = (37usize, 96usize);
    let b = block("obs", p, n, k, 120);
    let val = ValFeatures::try_prepare_tasks(&[&feats(2, k, 121)], p).unwrap();
    let reg = Arc::new(Registry::new());
    obs::with_registry(reg.clone(), || {
        scores_rows(&b.rows(), &val);
        scores_rows(&b.rows(), &val);
    });
    let name = format!(
        "kernel_scan_rows_total{{variant=\"{}\",bits=\"8\"}}",
        cpu::active().label()
    );
    let snap = reg.snapshot();
    assert_eq!(
        snap.counters.get(&name).copied().unwrap_or(0),
        2 * n as u64,
        "counter {name} must tick per scored row: {:?}",
        snap.counters
    );
    // ...and the process-global registry carries the dispatch-identity
    // gauge (set once, on first dispatch)
    let gname = format!("kernel_dispatch{{variant=\"{}\"}}", cpu::active().label());
    assert_eq!(
        obs::global().snapshot().gauges.get(&gname).copied().unwrap_or(0),
        1,
        "gauge {gname} must mark the active variant"
    );
}
