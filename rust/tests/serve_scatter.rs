//! Scatter-gather serving acceptance suite — the distributed layer's
//! contract on top of `tests/service_e2e.rs`:
//!
//! * **merge is exact**: a coordinator over 1..=3 local workers returns
//!   score vectors and top-k lists **byte-identical** to a direct
//!   `score_datastore_tasks` call — property-tested across worker count
//!   × bitwidth × scheme × shard geometry;
//! * **failures re-issue, answers never change**: a worker that fails its
//!   sub-query (fault-injecting fake) or dies outright (killed local
//!   worker) has its row range re-issued to a survivor and the merged
//!   answer stays bit-identical; when no worker can answer, the query
//!   degrades to a clean error — never a truncated answer;
//! * **generations pin consistently mid-ingest**: with workers on
//!   *different* generations of the same live store, every merged answer
//!   is the single-node answer for `(min generation, min rows)` —
//!   `since_gen` included — and the fleet converges as workers poll;
//! * **cascades scatter faithfully**: every sub-query of a cascade —
//!   including ranges *re-issued* after a worker fault — carries the same
//!   stage verb and precision as the wave that created it (never a plain
//!   exhaustive fallback), and a fleet whose stores lack the probe
//!   precision degrades the cascade to a clean error without poisoning
//!   subsequent plain queries.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use qless::datastore::{default_store_path, LiveStore, SegmentWriter};
use qless::grads::FeatureMatrix;
use qless::influence::{cascade_live_tasks, score_datastore_tasks, CascadeOpts, ScoreOpts};
use qless::prop_assert;
use qless::quant::{Precision, Scheme};
use qless::select::{top_k_scored, top_k_scored_since};
use qless::service::proto::{encode_response, parse_request, Request, Response};
use qless::service::{
    CascadeField, Client, Coordinator, CoordinatorOpts, ServeOpts, Server, ServiceStats,
    StatsReply,
};
use qless::util::prop::{normal_features as feats, run_prop, seeded_datastore};

fn tmp(tag: &str, name: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "qless_scatter_{tag}_{name}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ))
}

fn task(k: usize, ckpts: usize, seed: u64) -> Vec<FeatureMatrix> {
    (0..ckpts).map(|ci| feats(2, k, seed * 10 + ci as u64)).collect()
}

fn worker_opts(shard_rows: usize) -> ServeOpts {
    ServeOpts {
        addr: "127.0.0.1:0".into(),
        batch_window_ms: 0,
        workers: 2,
        shard_rows,
        ..Default::default()
    }
}

fn co_opts() -> CoordinatorOpts {
    CoordinatorOpts { addr: "127.0.0.1:0".into(), ..Default::default() }
}

/// The CI smoke: coordinator + 3 local workers + one query, merged answer
/// equals the direct library scan bit-for-bit.
#[test]
fn smoke_three_workers_match_direct_scan() {
    let (n, k) = (26usize, 64usize);
    let p = Precision::new(4, Scheme::Absmax).unwrap();
    let path = tmp("smoke", "store.qlds");
    let ds = seeded_datastore(&path, p, n, k, &[0.7, 0.3], 7);
    let val = task(k, 2, 3);
    let (want, _) = score_datastore_tasks(
        &ds,
        &[val.as_slice()],
        ScoreOpts { shard_rows: 5, ..Default::default() },
        None,
    )
    .unwrap();
    drop(ds);

    let co = Coordinator::start_local(&path, 3, worker_opts(5), co_opts()).unwrap();
    let mut c = Client::connect(co.addr()).unwrap();
    let r = c.score(&val, 4, true).unwrap();
    assert_eq!(r.top, top_k_scored(&want[0], 4));
    for (j, (a, b)) in want[0].iter().zip(r.scores.as_ref().unwrap()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "sample {j}");
    }
    assert!(r.rows.is_none(), "the coordinator's reply is a plain (unranged) answer");
    c.shutdown().unwrap();
    co.join().unwrap();
    std::fs::remove_file(path).ok();
}

/// The merge-exactness property: across worker count × bitwidth × scheme
/// × shard geometry × task count, merged scores and merged top-k equal
/// the direct fused scan bit-for-bit.
#[test]
fn prop_merged_answers_byte_identical_across_worker_counts() {
    run_prop("scatter-merge-invariant", 8, |g| {
        let bits = [1u8, 2, 4, 8, 16][g.rng.below(5)];
        let scheme = match bits {
            1 => Scheme::Sign,
            16 => Scheme::Absmax,
            _ => {
                if g.rng.below(2) == 0 {
                    Scheme::Absmax
                } else {
                    Scheme::Absmean
                }
            }
        };
        let p = Precision::new(bits, scheme).unwrap();
        let n = 6 + g.usize_up_to(34);
        let k = 64usize;
        let ckpts = 1 + g.rng.below(2);
        let etas: Vec<f32> = (0..ckpts).map(|c| 0.9 - 0.4 * c as f32).collect();
        let path = tmp("prop", &format!("{bits}b_{scheme:?}.qlds"));
        let ds = seeded_datastore(&path, p, n, k, &etas, 1000 + bits as u64);

        let q = 1 + g.rng.below(3);
        let tasks: Vec<Vec<FeatureMatrix>> =
            (0..q).map(|t| task(k, ckpts, 40 + t as u64)).collect();
        let refs: Vec<&[FeatureMatrix]> = tasks.iter().map(|t| t.as_slice()).collect();
        let (want, _) = score_datastore_tasks(&ds, &refs, ScoreOpts::default(), None).unwrap();
        drop(ds);

        let workers = 1 + g.rng.below(3);
        let shard_rows = 1 + g.rng.below(n + 2);
        let co =
            Coordinator::start_local(&path, workers, worker_opts(shard_rows), co_opts()).unwrap();
        let mut c = Client::connect(co.addr()).unwrap();
        for (t, val) in tasks.iter().enumerate() {
            let kk = 1 + g.rng.below(n + 2);
            let r = c.score(val, kk, true).unwrap();
            prop_assert!(
                r.top == top_k_scored(&want[t], kk),
                "{bits}-bit {scheme:?} workers={workers} task {t}: merged top-{kk} differs"
            );
            let got = r.scores.as_ref().unwrap();
            prop_assert!(got.len() == n, "score vector length");
            for (j, (a, b)) in want[t].iter().zip(got).enumerate() {
                prop_assert!(
                    a.to_bits() == b.to_bits(),
                    "{bits}-bit {scheme:?} workers={workers} shard_rows={shard_rows} \
                     task {t} sample {j}: merged {b} != direct {a}"
                );
            }
        }
        c.shutdown().unwrap();
        co.join().unwrap();
        std::fs::remove_file(path).ok();
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// fault injection
// ---------------------------------------------------------------------------

/// A protocol-conformant worker that answers `ping` and `stats` (so it
/// passes startup probes and health checks) but fails **every** score
/// sub-query with an error response — the deterministic way to force the
/// coordinator's re-issue path, which a genuinely dead worker cannot
/// (a dead worker fails its pre-query probe and is excluded up front).
/// Each score sub-query's cascade shape (`plain`, `probe@B`,
/// `rerank@B×rows`, `full`) is recorded in `seen` so tests can assert the
/// re-issue machinery preserves stage verbs and precisions.
struct FakeWorker {
    addr: SocketAddr,
    score_hits: Arc<AtomicUsize>,
    seen: Arc<Mutex<Vec<String>>>,
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl FakeWorker {
    fn start(k: usize, checkpoints: usize, bits: u8, n: usize, generation: u64) -> FakeWorker {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let score_hits = Arc::new(AtomicUsize::new(0));
        let seen = Arc::new(Mutex::new(Vec::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let accept = std::thread::spawn({
            let (hits, stop) = (Arc::clone(&score_hits), Arc::clone(&stop));
            let seen = Arc::clone(&seen);
            move || {
                for conn in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    let hits = Arc::clone(&hits);
                    let seen = Arc::clone(&seen);
                    std::thread::spawn(move || {
                        let mut reader = BufReader::new(stream.try_clone().unwrap());
                        let mut writer = stream;
                        let mut line = String::new();
                        loop {
                            line.clear();
                            match reader.read_line(&mut line) {
                                Ok(0) | Err(_) => break,
                                Ok(_) if line.trim().is_empty() => continue,
                                Ok(_) => {}
                            }
                            let resp = match parse_request(&line) {
                                Ok(Request::Ping { id }) => Response::Pong { id },
                                Ok(Request::Stats { id, .. }) => Response::Stats(StatsReply {
                                    id,
                                    generation,
                                    n_samples: n,
                                    k,
                                    checkpoints,
                                    bits,
                                    stats: ServiceStats::default(),
                                    per_worker: None,
                                }),
                                // an OLD worker predating the `metrics` verb
                                // parses it as an unknown op and answers with
                                // the error its parser produces — the
                                // coordinator must skip it, not fail the scrape
                                Ok(Request::Metrics { id, .. }) => Response::Error {
                                    id,
                                    error: "unknown op 'metrics' (expected score|stats|ping|shutdown)"
                                        .into(),
                                },
                                Ok(Request::Score(r)) => {
                                    hits.fetch_add(1, Ordering::SeqCst);
                                    seen.lock().unwrap().push(match &r.cascade {
                                        None => "plain".to_string(),
                                        Some(CascadeField::Full { .. }) => "full".to_string(),
                                        Some(CascadeField::Probe { probe }) => {
                                            format!("probe@{probe}")
                                        }
                                        Some(CascadeField::Rerank { rerank, rows }) => {
                                            format!("rerank@{rerank}x{}", rows.len())
                                        }
                                    });
                                    Response::Error {
                                        id: r.id,
                                        error: "injected fault: scores unavailable".into(),
                                    }
                                }
                                Ok(Request::Shutdown { id }) => Response::ShuttingDown { id },
                                Err(_) => continue,
                            };
                            let mut out = encode_response(&resp);
                            out.push('\n');
                            if writer.write_all(out.as_bytes()).is_err() {
                                break;
                            }
                        }
                    });
                }
            }
        });
        FakeWorker { addr, score_hits, seen, stop, accept: Some(accept) }
    }

    fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

/// A worker that passes probes but fails its sub-query gets its range
/// re-issued to a survivor — and the merged answer is byte-identical to
/// the no-fault answer.
#[test]
fn failed_subquery_is_reissued_and_the_answer_is_unchanged() {
    let (n, k) = (31usize, 64usize);
    let p = Precision::new(4, Scheme::Absmax).unwrap();
    let path = tmp("reissue", "store.qlds");
    let ds = seeded_datastore(&path, p, n, k, &[0.7, 0.3], 21);
    let val = task(k, 2, 8);
    let (want, _) = score_datastore_tasks(
        &ds,
        &[val.as_slice()],
        ScoreOpts { shard_rows: 5, ..Default::default() },
        None,
    )
    .unwrap();
    drop(ds);

    let w1 = Server::start(&path, worker_opts(5)).unwrap();
    let w2 = Server::start(&path, worker_opts(5)).unwrap();
    let fake = FakeWorker::start(k, 2, 4, n, 0);
    let co = Coordinator::start(CoordinatorOpts {
        addr: "127.0.0.1:0".into(),
        workers: vec![
            w1.addr().to_string(),
            w2.addr().to_string(),
            fake.addr.to_string(),
        ],
        ..Default::default()
    })
    .unwrap();

    let mut c = Client::connect(co.addr()).unwrap();
    let r = c.score(&val, 6, true).unwrap();
    assert!(
        fake.score_hits.load(Ordering::SeqCst) >= 1,
        "the faulty worker must have been handed a range"
    );
    assert_eq!(r.top, top_k_scored(&want[0], 6), "top-k despite a failed worker");
    for (j, (a, b)) in want[0].iter().zip(r.scores.as_ref().unwrap()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "sample {j}: re-issued merge differs");
    }

    c.shutdown().unwrap();
    co.join().unwrap();
    for w in [w1, w2] {
        w.stop();
        w.join().unwrap();
    }
    fake.stop();
    std::fs::remove_file(path).ok();
}

/// When every worker fails its sub-query the retry budget runs out and
/// the query degrades to a clean error response — the client sees a
/// failure, never a silently truncated score vector.
#[test]
fn exhausted_retries_degrade_to_a_clean_error() {
    let (n, k) = (12usize, 64usize);
    let fake = FakeWorker::start(k, 1, 8, n, 0);
    let co = Coordinator::start(CoordinatorOpts {
        addr: "127.0.0.1:0".into(),
        workers: vec![fake.addr.to_string()],
        retries: 2,
        ..Default::default()
    })
    .unwrap();
    let mut c = Client::connect(co.addr()).unwrap();
    let err = c.score(&task(k, 1, 5), 3, true).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("unanswered"), "degrade must name the unanswered range: {msg}");
    c.shutdown().unwrap();
    co.join().unwrap();
    fake.stop();
}

/// A cascade whose probe/rerank sub-queries hit a faulty worker has the
/// failed ranges re-issued **at the same stage verb and precision** — a
/// re-issued probe slice stays a 1-bit probe, a re-issued candidate chunk
/// stays an 8-bit rerank, and the merged top list is byte-identical to
/// the direct library cascade. No sub-query ever falls back to a plain
/// exhaustive scan.
#[test]
fn failed_cascade_subquery_is_reissued_at_the_same_stage_and_precision() {
    let (n, k) = (31usize, 64usize);
    let etas = [0.7f32, 0.3];
    let p1 = Precision::new(1, Scheme::Sign).unwrap();
    let p8 = Precision::new(8, Scheme::Absmax).unwrap();
    let dir = tmp("casc_reissue", "run");
    std::fs::create_dir_all(&dir).unwrap();
    let probe_path = default_store_path(&dir, p1);
    seeded_datastore(&probe_path, p1, n, k, &etas, 21);
    seeded_datastore(&default_store_path(&dir, p8), p8, n, k, &etas, 21);
    let val = task(k, 2, 8);
    // the no-fault reference: the direct library cascade over the pair
    // (a single-task scattered cascade is exact at any multiplier)
    let probe_live = LiveStore::open(&probe_path).unwrap();
    let rerank_live = LiveStore::open(&default_store_path(&dir, p8)).unwrap();
    let want = cascade_live_tasks(
        &probe_live,
        &rerank_live,
        &[val.as_slice()],
        CascadeOpts { k: 4, mult: 2, scan: ScoreOpts { shard_rows: 5, ..Default::default() } },
    )
    .unwrap()
    .top;

    let w1 = Server::start(&probe_path, worker_opts(5)).unwrap();
    let w2 = Server::start(&probe_path, worker_opts(5)).unwrap();
    let fake = FakeWorker::start(k, 2, 1, n, 0);
    let co = Coordinator::start(CoordinatorOpts {
        addr: "127.0.0.1:0".into(),
        workers: vec![
            w1.addr().to_string(),
            w2.addr().to_string(),
            fake.addr.to_string(),
        ],
        ..Default::default()
    })
    .unwrap();

    let mut c = Client::connect(co.addr()).unwrap();
    let r = c.score_cascade(&val, 4, 1, 8, 2).unwrap();
    assert!(
        fake.score_hits.load(Ordering::SeqCst) >= 1,
        "the faulty worker must have been handed a cascade sub-query"
    );
    for shape in fake.seen.lock().unwrap().iter() {
        assert!(
            shape == "probe@1" || shape.starts_with("rerank@8x"),
            "cascade sub-query reached a worker as '{shape}' — stage verb or precision lost"
        );
    }
    let got: Vec<(usize, u32)> = r.top.iter().map(|(i, s)| (*i, s.to_bits())).collect();
    let exp: Vec<(usize, u32)> = want[0].iter().map(|(i, s)| (*i, s.to_bits())).collect();
    assert_eq!(got, exp, "re-issued cascade differs from the library cascade");

    c.shutdown().unwrap();
    co.join().unwrap();
    for w in [w1, w2] {
        w.stop();
        w.join().unwrap();
    }
    fake.stop();
    std::fs::remove_dir_all(&dir).ok();
}

/// A fleet whose run directory holds only the rerank precision cannot
/// probe: the cascade degrades to a clean error (every worker refuses the
/// probe stage), and the failure poisons nothing — the very next plain
/// query on the same connection gets the byte-exact merged answer once
/// the pre-query probe restores worker health.
#[test]
fn cascade_missing_probe_precision_degrades_cleanly_and_the_fleet_recovers() {
    let (n, k) = (14usize, 64usize);
    let p8 = Precision::new(8, Scheme::Absmax).unwrap();
    let dir = tmp("casc_missing", "run");
    std::fs::create_dir_all(&dir).unwrap();
    let path = default_store_path(&dir, p8);
    let ds = seeded_datastore(&path, p8, n, k, &[1.0], 31);
    let val = task(k, 1, 9);
    let (want, _) =
        score_datastore_tasks(&ds, &[val.as_slice()], ScoreOpts::default(), None).unwrap();
    drop(ds);

    let co = Coordinator::start_local(&path, 2, worker_opts(4), co_opts()).unwrap();
    let mut c = Client::connect(co.addr()).unwrap();
    let err = c.score_cascade(&val, 3, 1, 8, 4).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("unanswered"), "degrade must be a clean error: {msg}");
    let r = c.score(&val, 3, true).unwrap();
    assert_eq!(r.top, top_k_scored(&want[0], 3), "plain queries must survive the failed cascade");
    for (j, (a, b)) in want[0].iter().zip(r.scores.as_ref().unwrap()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "sample {j} after the failed cascade");
    }
    c.shutdown().unwrap();
    co.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Killing a local worker outright (process-death model: its listener
/// goes away) drops it from the fleet at the next probe and the
/// remaining workers still produce the byte-identical answer.
#[test]
fn killed_local_worker_does_not_change_the_answer() {
    let (n, k) = (27usize, 64usize);
    let p = Precision::new(2, Scheme::Absmax).unwrap();
    let path = tmp("kill", "store.qlds");
    let ds = seeded_datastore(&path, p, n, k, &[1.0], 13);
    let val = task(k, 1, 17);
    let (want, _) =
        score_datastore_tasks(&ds, &[val.as_slice()], ScoreOpts::default(), None).unwrap();
    drop(ds);

    let co = Coordinator::start_local(&path, 3, worker_opts(4), co_opts()).unwrap();
    let mut c = Client::connect(co.addr()).unwrap();
    let before = c.score(&val, 5, true).unwrap();
    assert_eq!(before.top, top_k_scored(&want[0], 5));

    // kill one worker; give its listener a moment to actually close
    co.local_workers()[1].stop();
    std::thread::sleep(Duration::from_millis(150));

    let after = c.score(&val, 5, true).unwrap();
    assert_eq!(after.top, before.top, "top-k across a worker death");
    let (a, b) = (before.scores.unwrap(), after.scores.unwrap());
    for (j, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "sample {j}: answer changed after worker death");
    }
    c.shutdown().unwrap();
    co.join().unwrap();
    std::fs::remove_file(path).ok();
}

// ---------------------------------------------------------------------------
// generations
// ---------------------------------------------------------------------------

/// Append rows `lo..hi` of the canonical `seeded_datastore` feature
/// stream as one generation (same idiom as `tests/ingest.rs`).
fn ingest_range(dir: &Path, p: Precision, lo: usize, hi: usize, n_total: usize, k: usize, etas: &[f32], seed: u64) {
    let mut sw = SegmentWriter::create(dir, &[p], hi - lo, 0).unwrap();
    for ci in 0..etas.len() {
        sw.begin_checkpoint().unwrap();
        let f = feats(n_total, k, seed + ci as u64);
        sw.append_rows(&f.data[lo * k..hi * k]).unwrap();
        sw.end_checkpoint().unwrap();
    }
    sw.finalize().unwrap();
}

/// The consistency property under live ingest: with workers genuinely on
/// *different* generations of the same store, every merged answer equals
/// the single-node answer for the pinned `(min generation, min rows)`
/// state — `since_gen` filtering included — and once every worker has
/// polled the new generation the fleet serves the full live store.
#[test]
fn since_gen_is_consistent_with_workers_on_different_generations() {
    let (n0, add, k) = (18usize, 7usize, 64usize);
    let n_total = n0 + add;
    let etas = [0.6f32, 0.4];
    let p = Precision::new(4, Scheme::Absmax).unwrap();
    let dir = tmp("gen", "run");
    std::fs::create_dir_all(&dir).unwrap();
    let base = default_store_path(&dir, p);
    seeded_datastore(&base, p, n0, k, &etas, 42);
    // monolithic fixtures: the gen-0 answer and the full live answer
    let mono0 = dir.join("mono0.qlds");
    let ds0 = seeded_datastore(&mono0, p, n0, k, &etas, 42);
    let mono1 = dir.join("mono1.qlds");
    let ds1 = seeded_datastore(&mono1, p, n_total, k, &etas, 42);
    let val = task(k, 2, 33);
    let opts = ScoreOpts { shard_rows: 5, ..Default::default() };
    let (want0, _) = score_datastore_tasks(&ds0, &[val.as_slice()], opts, None).unwrap();
    let (want1, _) = score_datastore_tasks(&ds1, &[val.as_slice()], opts, None).unwrap();
    drop((ds0, ds1));

    let co = Coordinator::start_local(&base, 3, worker_opts(5), co_opts()).unwrap();
    let mut c = Client::connect(co.addr()).unwrap();

    // generation 0: everyone agrees
    let r0 = c.score(&val, 4, true).unwrap();
    assert_eq!(r0.generation, 0);
    assert_eq!(r0.scores.as_ref().unwrap().len(), n0);

    // ingest mid-serve, then advance ONLY worker 0 (a ranged sub-query
    // makes it poll): the fleet is now split across generations 1 and 0
    ingest_range(&dir, p, n0, n_total, n_total, k, &etas, 42);
    let mut w0 = Client::connect(co.local_workers()[0].addr()).unwrap();
    let adv = w0.score_rows(&val, 1, false, None, Some((0, 4))).unwrap();
    assert_eq!(adv.generation, 1, "worker 0 must have polled the ingest");
    let st0 = w0.stats().unwrap();
    assert_eq!((st0.generation, st0.n_samples), (1, n_total));
    let st2 = Client::connect(co.local_workers()[2].addr()).unwrap().stats().unwrap();
    assert_eq!((st2.generation, st2.n_samples), (0, n0), "worker 2 still on generation 0");

    // fleet stats pin to the minimum the whole fleet can answer for
    let fleet = c.stats().unwrap();
    assert_eq!((fleet.generation, fleet.n_samples), (0, n0));

    // a mixed-generation query serves the pinned (0, n0) state exactly:
    // bit-identical to the gen-0 single-node answer, no tearing — and
    // since_gen=0 finds nothing because no *served* row is newer
    let r1 = c.score_since(&val, 4, true, Some(0)).unwrap();
    assert_eq!(r1.generation, 0, "mixed fleet pins to min generation");
    let got = r1.scores.as_ref().unwrap();
    assert_eq!(got.len(), n0, "mixed fleet pins to min rows");
    for (j, (a, b)) in want0[0].iter().zip(got).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "sample {j}: pinned answer vs gen-0 scan");
    }
    assert!(r1.top.is_empty(), "since_gen=0 at pinned gen 0 ranks nothing");

    // that query's ranged sub-scans made every worker poll: the fleet
    // converges and now serves the full live store
    let r2 = c.score_since(&val, add + 5, true, Some(0)).unwrap();
    assert_eq!(r2.generation, 1, "fleet converged to the ingested generation");
    let full = r2.scores.as_ref().unwrap();
    assert_eq!(full.len(), n_total);
    for (j, (a, b)) in want1[0].iter().zip(full).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "sample {j}: converged answer vs live scan");
    }
    // since_gen=0 now ranks exactly the ingested tail, merged across
    // workers with the same comparator a single node uses
    assert_eq!(r2.top, top_k_scored_since(&want1[0], add + 5, n0));
    assert!(r2.top.iter().all(|(i, _)| *i >= n0), "{:?}", r2.top);

    c.shutdown().unwrap();
    co.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// observability
// ---------------------------------------------------------------------------

/// `stats` with `"per_worker":true` against a coordinator returns one row
/// per live worker — address, pinned generation, row count, per-worker
/// accounting — while the flagless request keeps the wire shape it always
/// had (no array).
#[test]
fn per_worker_stats_breakdown_lists_every_live_worker() {
    let (n, k) = (19usize, 64usize);
    let p = Precision::new(4, Scheme::Absmax).unwrap();
    let path = tmp("perworker", "store.qlds");
    seeded_datastore(&path, p, n, k, &[0.8, 0.2], 51);

    let co = Coordinator::start_local(&path, 2, worker_opts(5), co_opts()).unwrap();
    let mut c = Client::connect(co.addr()).unwrap();
    let val = task(k, 2, 12);
    c.score(&val, 3, false).unwrap();

    let plain = c.stats().unwrap();
    assert!(plain.per_worker.is_none(), "the breakdown must be opt-in");
    let detail = c.stats_detail(true).unwrap();
    let ws = detail.per_worker.as_ref().expect("per_worker:true returns the breakdown");
    assert_eq!(ws.len(), 2, "one row per live worker");
    let fleet_addrs: Vec<String> =
        co.local_workers().iter().map(|w| w.addr().to_string()).collect();
    for w in ws {
        assert!(fleet_addrs.contains(&w.addr), "unknown worker addr {}", w.addr);
        assert_eq!(w.generation, detail.generation, "uniform fleet pins one generation");
        assert_eq!(w.n_samples, n, "each local worker serves the full store");
    }
    assert!(
        ws.iter().map(|w| w.stats.queries).sum::<u64>() >= 2,
        "the scattered score must show up in the per-worker query counts"
    );
    c.shutdown().unwrap();
    co.join().unwrap();
    std::fs::remove_file(path).ok();
}

/// A fleet metrics scrape must survive a worker that predates the
/// `metrics` verb: the old worker's unknown-op error is counted and
/// skipped — the merged scrape still answers, and the worker stays in the
/// fleet for the verbs it does speak.
#[test]
fn metrics_scrape_skips_workers_without_the_verb() {
    let (n, k) = (16usize, 64usize);
    let p = Precision::new(8, Scheme::Absmax).unwrap();
    let path = tmp("oldworker", "store.qlds");
    seeded_datastore(&path, p, n, k, &[1.0], 61);

    let w = Server::start(&path, worker_opts(4)).unwrap();
    let fake = FakeWorker::start(k, 1, 8, n, 0);
    let co = Coordinator::start(CoordinatorOpts {
        addr: "127.0.0.1:0".into(),
        workers: vec![w.addr().to_string(), fake.addr.to_string()],
        ..Default::default()
    })
    .unwrap();

    let mut c = Client::connect(co.addr()).unwrap();
    let m = c.metrics(false, false).unwrap();
    assert!(
        m.snapshot.counters.get("coord_metrics_skipped_total").copied().unwrap_or(0) >= 1,
        "the verb-less worker must be counted as skipped, not fail the scrape"
    );
    // the skip must not flip the worker's health flag: a per-worker stats
    // breakdown right after the scrape still lists BOTH workers
    let detail = c.stats_detail(true).unwrap();
    assert_eq!(
        detail.per_worker.as_ref().map(Vec::len),
        Some(2),
        "both workers still in the fleet after the degraded scrape"
    );
    c.shutdown().unwrap();
    co.join().unwrap();
    w.stop();
    w.join().unwrap();
    fake.stop();
    std::fs::remove_file(path).ok();
}
