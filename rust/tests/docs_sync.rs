//! Documentation-sync checks — CI's guard against docs drifting from the
//! code:
//!
//! * every `--flag` a doc shows in a `qless` invocation must exist in the
//!   parser (greps the documented flags against `usage_for`'s output and
//!   the `Config` key set);
//! * every `Config` key must be documented in the usage texts (a new knob
//!   cannot ship undocumented);
//! * every relative markdown link in the repo's docs must point at a file
//!   that exists (FORMAT.md / PROTOCOL.md are load-bearing: rustdoc
//!   includes them, ARCHITECTURE/README link to them).

use std::collections::BTreeSet;
use std::path::Path;

use qless::config::cli::usage_for;
use qless::config::Config;

/// The documentation set under sync enforcement. Paths are relative to
/// the repository root; the normative specs live with the workspace
/// crates that compile them into rustdoc.
const DOCS: &[(&str, &str)] = &[
    ("README.md", include_str!("../../README.md")),
    ("rust/ARCHITECTURE.md", include_str!("../ARCHITECTURE.md")),
    ("rust/DESIGN.md", include_str!("../DESIGN.md")),
    ("rust/EXPERIMENTS.md", include_str!("../EXPERIMENTS.md")),
    (
        "rust/crates/qless-datastore/FORMAT.md",
        include_str!("../crates/qless-datastore/FORMAT.md"),
    ),
    (
        "rust/crates/qless-service/PROTOCOL.md",
        include_str!("../crates/qless-service/PROTOCOL.md"),
    ),
];

/// Collect every `--flag` token on `line` into `out`.
fn extract_flags(line: &str, out: &mut BTreeSet<String>) {
    let mut i = 0usize;
    while let Some(pos) = line[i..].find("--") {
        let start = i + pos + 2;
        let end = line[start..]
            .find(|c: char| !(c.is_ascii_lowercase() || c.is_ascii_digit() || c == '-'))
            .map(|e| start + e)
            .unwrap_or(line.len());
        if end > start && line.as_bytes()[start].is_ascii_lowercase() {
            out.insert(line[start..end].trim_end_matches('-').to_string());
        }
        i = end.max(start);
    }
}

/// Every flag the CLI actually accepts: the Config keys (dash form) plus
/// the parser-level flags.
fn known_flags() -> BTreeSet<String> {
    let mut known: BTreeSet<String> = Config::KEYS.iter().map(|k| k.replace('_', "-")).collect();
    // parser-level flags plus the usage screens' literal `--key value`
    // placeholder (it names the convention, not a flag)
    for extra in ["config", "fast", "help", "key", "traces"] {
        known.insert(extra.to_string());
    }
    known
}

#[test]
fn documented_qless_flags_exist_in_the_parser() {
    let known = known_flags();
    for (name, text) in DOCS {
        for (lineno, line) in text.lines().enumerate() {
            // only lines demonstrating qless invocations/flags; cargo
            // command lines carry cargo's own flags
            if !line.contains("qless") || line.contains("cargo") {
                continue;
            }
            let mut flags = BTreeSet::new();
            extract_flags(line, &mut flags);
            for f in flags {
                assert!(
                    known.contains(&f),
                    "{name}:{}: documents `--{f}`, which the CLI does not accept \
                     (known flags: Config::KEYS + config/fast/help)",
                    lineno + 1
                );
            }
        }
    }
}

#[test]
fn usage_texts_document_every_config_key() {
    // the union of the global and serve usage screens (usage_for output)
    // must mention every settable key, dash form
    let all = format!("{}\n{}", usage_for(""), usage_for("serve"));
    let mut usage_flags = BTreeSet::new();
    for line in all.lines() {
        extract_flags(line, &mut usage_flags);
    }
    for key in Config::KEYS {
        let dash = key.replace('_', "-");
        assert!(
            usage_flags.contains(&dash),
            "Config key '{key}' is not documented as --{dash} in USAGE/SERVE_USAGE"
        );
    }
    // and the usage screens never invent flags the parser rejects
    let known = known_flags();
    for f in &usage_flags {
        assert!(known.contains(f), "usage documents `--{f}`, which no Config key backs");
    }
}

/// Environment variables the runtime actually reads (grep `std::env::var`
/// before growing this list). The docs may only reference these, and each
/// must be documented where users look first.
const KNOWN_ENV_VARS: &[&str] = &["QLESS_KERNEL", "QLESS_SCORE_THREADS"];

#[test]
fn documented_env_vars_exist_and_are_documented() {
    // every `QLESS_*` token any doc mentions must be a real knob...
    for (name, text) in DOCS {
        for (lineno, line) in text.lines().enumerate() {
            let mut rest = *line;
            while let Some(pos) = rest.find("QLESS_") {
                let tok: String = rest[pos..]
                    .chars()
                    .take_while(|c| c.is_ascii_uppercase() || *c == '_' || c.is_ascii_digit())
                    .collect();
                assert!(
                    KNOWN_ENV_VARS.contains(&tok.as_str()),
                    "{name}:{}: documents `{tok}`, which the runtime does not read \
                     (known: {KNOWN_ENV_VARS:?})",
                    lineno + 1
                );
                rest = &rest[pos + tok.len()..];
            }
        }
    }
    // ...and every real knob must be documented in the user-facing docs
    // (README or ARCHITECTURE), so a new env var cannot ship silent
    let user_docs: String = DOCS
        .iter()
        .filter(|(n, _)| n.ends_with("README.md") || n.ends_with("ARCHITECTURE.md"))
        .map(|(_, t)| *t)
        .collect();
    for var in KNOWN_ENV_VARS {
        assert!(
            user_docs.contains(var),
            "env var {var} is not documented in README.md or rust/ARCHITECTURE.md"
        );
    }
}

#[test]
fn relative_markdown_links_resolve() {
    let crate_root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let repo_root = crate_root.parent().expect("crate lives in repo/rust");
    for (name, text) in DOCS {
        // resolve each doc's links against its OWN directory, wherever in
        // the workspace it lives — the spec docs moved into their crates
        let doc_dir = repo_root.join(Path::new(name).parent().expect("repo-relative path"));
        let mut i = 0usize;
        while let Some(pos) = text[i..].find("](") {
            let start = i + pos + 2;
            let Some(close) = text[start..].find(')') else { break };
            let target = &text[start..start + close];
            i = start + close;
            if target.is_empty()
                || target.starts_with("http://")
                || target.starts_with("https://")
                || target.starts_with('#')
                || target.starts_with("mailto:")
            {
                continue;
            }
            let file = target.split('#').next().unwrap_or(target);
            let resolved = doc_dir.join(file);
            assert!(
                resolved.exists(),
                "{name}: broken relative link `{target}` (resolved to {resolved:?})"
            );
        }
    }
}

#[test]
fn spec_docs_are_included_in_rustdoc() {
    // FORMAT.md / PROTOCOL.md are kept honest by being compiled into the
    // rustdoc of their modules (their examples run as doctests). Guard
    // the include wiring itself: the markdown files must contain the
    // examples the modules promise.
    let (_, format_md) = DOCS.iter().find(|(n, _)| n.ends_with("FORMAT.md")).unwrap();
    assert!(format_md.contains("```rust"), "FORMAT.md lost its doctest example");
    assert!(format_md.contains("51 4c 44 53"), "FORMAT.md lost its hex dump");
    // the IVF index sidecar spec: section marker + the QIDX magic in hex
    assert!(
        format_md.contains("## Index sidecar (`.qidx`)"),
        "FORMAT.md lost the index sidecar section"
    );
    assert!(format_md.contains("51 49 44 58"), "FORMAT.md lost the QIDX magic hex");
    let (_, proto_md) = DOCS.iter().find(|(n, _)| n.ends_with("PROTOCOL.md")).unwrap();
    assert!(proto_md.contains("```rust"), "PROTOCOL.md lost its doctest example");
    assert!(proto_md.contains("since_gen"), "PROTOCOL.md lost the generation filter");
    assert!(proto_md.contains("rows"), "PROTOCOL.md lost the scatter-gather worker verb");
    assert!(
        proto_md.contains("## Indexed scoring") && proto_md.contains("nprobe"),
        "PROTOCOL.md lost the indexed-scoring section"
    );
}
