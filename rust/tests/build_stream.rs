//! Streaming multi-precision builder equivalence suite — the tentpole's
//! acceptance contract:
//!
//! * one [`MultiWriter`] pass over a feature-row stream produces datastore
//!   files **byte-identical** to the legacy in-RAM path (dense features →
//!   per-precision `append_features` loop), across bitwidth × scheme ×
//!   quantize-worker count × window size — including windows that do not
//!   divide `n`;
//! * influence scores over the streamed store equal the legacy store's
//!   exactly, and so do the scores the resident service serves (the score
//!   cache keys on task digest × datastore generation, so byte-equal files
//!   ⇒ identical served answers);
//! * `worker_count_digest_smoke` is the CI smoke: build at two worker
//!   counts, diff the file digests.

use std::path::{Path, PathBuf};

use qless::datastore::{Datastore, MultiWriter};
use qless::influence::{score_datastore_tasks, ScoreOpts};
use qless::prop_assert;
use qless::quant::{Precision, Scheme};
use qless::service::{ScoreQuery, Session, SessionOpts};
use qless::util::prop::{normal_features, run_prop, seeded_datastore};

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "qless_buildstream_{tag}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Every precision the format supports, both schemes where they differ.
fn full_grid() -> Vec<Precision> {
    vec![
        Precision::new(16, Scheme::Absmax).unwrap(),
        Precision::new(8, Scheme::Absmax).unwrap(),
        Precision::new(8, Scheme::Absmean).unwrap(),
        Precision::new(4, Scheme::Absmax).unwrap(),
        Precision::new(4, Scheme::Absmean).unwrap(),
        Precision::new(2, Scheme::Absmax).unwrap(),
        Precision::new(2, Scheme::Absmean).unwrap(),
        Precision::new(1, Scheme::Sign).unwrap(),
    ]
}

/// Stream `normal_features(n, k, seed + ci)` rows (the exact layout
/// `seeded_datastore` writes) through a `MultiWriter` in `window`-row
/// chunks with `workers` quantize workers.
fn stream_build(
    dir: &Path,
    precisions: &[Precision],
    n: usize,
    k: usize,
    etas: &[f32],
    seed: u64,
    window: usize,
    workers: usize,
) -> Vec<(Precision, PathBuf)> {
    let targets: Vec<(Precision, PathBuf)> = precisions
        .iter()
        .map(|p| (*p, dir.join(format!("stream_{}b_{}.qlds", p.bits, p.scheme))))
        .collect();
    let mut mw = MultiWriter::create(&targets, n, k, etas.len(), workers).unwrap();
    for (ci, &eta) in etas.iter().enumerate() {
        let f = normal_features(n, k, seed + ci as u64);
        mw.begin_checkpoint(eta).unwrap();
        let mut row = 0usize;
        while row < n {
            let take = window.min(n - row);
            mw.append_rows(&f.data[row * k..(row + take) * k]).unwrap();
            row += take;
        }
        mw.end_checkpoint().unwrap();
    }
    assert!(mw.peak_builder_bytes() > 0);
    mw.finalize().unwrap();
    targets
}

#[test]
fn prop_streaming_build_is_byte_identical_to_legacy() {
    run_prop("stream-vs-legacy", 40, |g| {
        let n = 3 + g.usize_up_to(40);
        let k = 8 * (1 + g.usize_up_to(12)); // 8..104 dims
        let ckpts = 1 + g.rng.below(3);
        let etas: Vec<f32> = (0..ckpts).map(|c| 0.1 + 0.3 * c as f32).collect();
        let seed = g.rng.below(1 << 20) as u64;
        let window = 1 + g.rng.below(n + 4); // may exceed or not divide n
        let workers = g.rng.below(5); // 0 = uncapped pool
        let dir = tmpdir("prop");
        let grid = full_grid();
        let targets = stream_build(&dir, &grid, n, k, &etas, seed, window, workers);
        for (p, path) in &targets {
            let legacy = dir.join(format!("legacy_{}b_{}.qlds", p.bits, p.scheme));
            seeded_datastore(&legacy, *p, n, k, &etas, seed);
            let got = std::fs::read(path).unwrap();
            let want = std::fs::read(&legacy).unwrap();
            prop_assert!(
                got == want,
                "{} differs (n={n} k={k} ckpts={ckpts} window={window} workers={workers})",
                p.label()
            );
        }
        std::fs::remove_dir_all(&dir).ok();
        Ok(())
    });
}

#[test]
fn streamed_store_scores_and_serves_identically() {
    // Byte-equality already implies this; asserting it end-to-end guards
    // the integration seams (open → scan → serve) against regressions that
    // byte-compare alone would miss if the fixture ever drifted.
    let dir = tmpdir("scores");
    let (n, k) = (23usize, 64usize);
    let etas = [0.8f32, 0.3];
    let seed = 5u64;
    let grid = full_grid();
    let targets = stream_build(&dir, &grid, n, k, &etas, seed, 7, 2);
    for (p, path) in &targets {
        let legacy_path = dir.join(format!("legacy_{}b_{}.qlds", p.bits, p.scheme));
        let legacy = seeded_datastore(&legacy_path, *p, n, k, &etas, seed);
        let streamed = Datastore::open(path).unwrap();
        let task: Vec<_> = (0..etas.len()).map(|c| normal_features(3, k, 900 + c as u64)).collect();

        let (a, _) = score_datastore_tasks(
            &streamed,
            &[task.as_slice()],
            ScoreOpts { shard_rows: 5, ..Default::default() },
            None,
        )
        .unwrap();
        let (b, _) = score_datastore_tasks(
            &legacy,
            &[task.as_slice()],
            ScoreOpts { shard_rows: 5, ..Default::default() },
            None,
        )
        .unwrap();
        assert_eq!(a, b, "{}: streamed vs legacy scan scores", p.label());

        // served answers: same query against both stores, identical scores
        let mut s1 = Session::open(path, SessionOpts::default()).unwrap();
        let mut s2 = Session::open(&legacy_path, SessionOpts::default()).unwrap();
        let q = || ScoreQuery { val: task.clone() };
        let r1 = s1.answer_batch(&[q()]).unwrap();
        let r2 = s2.answer_batch(&[q()]).unwrap();
        assert_eq!(r1[0].scores, r2[0].scores, "{}: served scores", p.label());
        assert_eq!(*r1[0].scores, a[0], "{}: served vs direct scan", p.label());
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// CI smoke: run the streaming builder at two worker counts and diff the
/// produced files (via a content digest). Fast — one small geometry, the
/// full precision grid.
#[test]
fn worker_count_digest_smoke() {
    let digest = |bytes: &[u8]| -> u64 {
        // FNV-1a, enough to diff two local builds
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        h
    };
    let (n, k) = (19usize, 96usize);
    let etas = [1.0f32];
    let grid = full_grid();
    let dir1 = tmpdir("w1");
    let dir2 = tmpdir("w2");
    let t1 = stream_build(&dir1, &grid, n, k, &etas, 3, 4, 1);
    let t2 = stream_build(&dir2, &grid, n, k, &etas, 3, 4, 8);
    for ((p, a), (_, b)) in t1.iter().zip(&t2) {
        let da = digest(&std::fs::read(a).unwrap());
        let db = digest(&std::fs::read(b).unwrap());
        assert_eq!(da, db, "{}: digest differs between 1 and 8 workers", p.label());
    }
    std::fs::remove_dir_all(&dir1).ok();
    std::fs::remove_dir_all(&dir2).ok();
}
