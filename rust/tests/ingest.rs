//! Incremental-ingest acceptance suite — the live-datastore tentpole's
//! contract:
//!
//! * **build-all-at-once == build-then-ingest**: a base store plus
//!   ingested segments holds byte-identical rows (and scales) to one
//!   monolithic store built from the same feature stream, and scores
//!   end-to-end identically ([`score_live_tasks`] vs
//!   `score_datastore_tasks`) — across bitwidth × scheme × ingest window
//!   × quantize-worker count, including projection dims whose packed rows
//!   end mid-byte (`k·bits % 8 ≠ 0`);
//! * **pre-existing bytes are never touched**: the base file's digest is
//!   invariant across ingests (asserted byte-for-byte);
//! * a **running `qless serve`** picks a new generation up without
//!   restart: cached answers extend with a tail scan over only the new
//!   rows, responses carry the bumped generation, and `since_gen` ranks
//!   only newer rows;
//! * a **crash mid-append** is detected and rolled back for every
//!   precision together, never served.

use std::path::{Path, PathBuf};

use qless::datastore::{
    default_store_path, repair_run_dir, segment_store_path, Datastore, LiveStore, SegmentWriter,
};
use qless::grads::FeatureMatrix;
use qless::influence::{score_datastore_tasks, score_live_tasks, ScoreOpts};
use qless::prop_assert;
use qless::quant::{Precision, Scheme};
use qless::select::top_k_scored_since;
use qless::service::{Client, ServeOpts, Server};
use qless::util::prop::{normal_features, run_prop, seeded_datastore};

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "qless_ingest_{tag}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Every precision the format supports, both schemes where they differ.
fn full_grid() -> Vec<Precision> {
    vec![
        Precision::new(16, Scheme::Absmax).unwrap(),
        Precision::new(8, Scheme::Absmax).unwrap(),
        Precision::new(8, Scheme::Absmean).unwrap(),
        Precision::new(4, Scheme::Absmax).unwrap(),
        Precision::new(4, Scheme::Absmean).unwrap(),
        Precision::new(2, Scheme::Absmax).unwrap(),
        Precision::new(2, Scheme::Absmean).unwrap(),
        Precision::new(1, Scheme::Sign).unwrap(),
    ]
}

/// Ingest rows `lo..hi` of the canonical feature stream
/// (`normal_features(n_total, k, seed + ci)` per checkpoint — the exact
/// stream `seeded_datastore` draws from, so base + segments reproduce a
/// monolithic `seeded_datastore(n_total)` row-for-row) as one generation,
/// streamed in `window`-row chunks with `workers` quantize workers.
#[allow(clippy::too_many_arguments)]
fn ingest_range(
    dir: &Path,
    grid: &[Precision],
    lo: usize,
    hi: usize,
    n_total: usize,
    k: usize,
    etas: &[f32],
    seed: u64,
    window: usize,
    workers: usize,
) {
    let mut sw = SegmentWriter::create(dir, grid, hi - lo, workers).unwrap();
    for ci in 0..etas.len() {
        sw.begin_checkpoint().unwrap();
        let f = normal_features(n_total, k, seed + ci as u64);
        let mut row = lo;
        while row < hi {
            let take = window.max(1).min(hi - row);
            sw.append_rows(&f.data[row * k..(row + take) * k]).unwrap();
            row += take;
        }
        sw.end_checkpoint().unwrap();
    }
    sw.finalize().unwrap();
}

#[test]
fn prop_build_then_ingest_matches_build_all_at_once() {
    run_prop("ingest-vs-monolithic", 14, |g| {
        let n0 = 3 + g.usize_up_to(16);
        let add1 = 1 + g.rng.below(7);
        let add2 = g.rng.below(6); // 0 = single-generation case
        let n_total = n0 + add1 + add2;
        // arbitrary k, deliberately NOT a multiple of 8 half the time, so
        // packed sub-byte rows end mid-byte (k·bits % 8 ≠ 0)
        let k = 5 + g.usize_up_to(60);
        let ckpts = 1 + g.rng.below(2);
        let etas: Vec<f32> = (0..ckpts).map(|c| 0.9 - 0.4 * c as f32).collect();
        let seed = g.rng.below(1 << 20) as u64;
        let window = 1 + g.rng.below(add1 + 3);
        let workers = g.rng.below(4);
        let dir = tmpdir("prop");
        let grid = full_grid();

        // base build (generation 0), digests captured before any ingest
        for &p in &grid {
            seeded_datastore(&default_store_path(&dir, p), p, n0, k, &etas, seed);
        }
        let digests: Vec<Vec<u8>> = grid
            .iter()
            .map(|&p| std::fs::read(default_store_path(&dir, p)).unwrap())
            .collect();

        ingest_range(&dir, &grid, n0, n0 + add1, n_total, k, &etas, seed, window, workers);
        if add2 > 0 {
            ingest_range(&dir, &grid, n0 + add1, n_total, n_total, k, &etas, seed, window, workers);
        }

        let t0: Vec<FeatureMatrix> =
            (0..ckpts).map(|c| normal_features(3, k, 7000 + c as u64)).collect();
        let t1: Vec<FeatureMatrix> =
            (0..ckpts).map(|c| normal_features(2, k, 8000 + c as u64)).collect();
        let tasks: Vec<&[FeatureMatrix]> = vec![&t0, &t1];
        let opts = ScoreOpts { shard_rows: 1 + g.rng.below(n_total + 2), ..Default::default() };

        for (gi, &p) in grid.iter().enumerate() {
            let base = default_store_path(&dir, p);
            prop_assert!(
                std::fs::read(&base).unwrap() == digests[gi],
                "{}: ingest modified pre-existing base bytes",
                p.label()
            );
            let mono_path = dir.join(format!("mono_{}b_{}.qlds", p.bits, p.scheme));
            let mono = seeded_datastore(&mono_path, p, n_total, k, &etas, seed);
            let live = LiveStore::open(&base).unwrap();
            prop_assert!(live.n_rows() == n_total, "{}: live rows", p.label());
            prop_assert!(
                live.generation() == if add2 > 0 { 2 } else { 1 },
                "{}: generation",
                p.label()
            );

            // row-for-row byte identity against the monolithic store
            for ci in 0..ckpts {
                let mono_block = mono.load_checkpoint(ci).unwrap();
                for member in live.members() {
                    let block = member.ds.load_checkpoint(ci).unwrap();
                    prop_assert!(
                        (block.eta.to_bits()) == mono_block.eta.to_bits(),
                        "{}: member η",
                        p.label()
                    );
                    for j in 0..block.n {
                        let gr = member.start_row + j;
                        prop_assert!(
                            block.row_bytes(j) == mono_block.row_bytes(gr),
                            "{} ckpt {ci} row {gr}: bytes differ (n0={n0} add1={add1} \
                             add2={add2} k={k} window={window} workers={workers})",
                            p.label()
                        );
                        if p.bits != 16 {
                            prop_assert!(
                                block.scales[j].to_bits() == mono_block.scales[gr].to_bits(),
                                "{} ckpt {ci} row {gr}: scale differs",
                                p.label()
                            );
                        }
                    }
                }
            }

            // end-to-end: live scan scores == monolithic scan scores
            let (want, _) = score_datastore_tasks(&mono, &tasks, opts, None).unwrap();
            let (got, _) = score_live_tasks(&live, &tasks, opts).unwrap();
            prop_assert!(
                got == want,
                "{}: live scores differ from monolithic (k={k} shard_rows={})",
                p.label(),
                opts.shard_rows
            );
        }
        std::fs::remove_dir_all(&dir).ok();
        Ok(())
    });
}

/// The serving acceptance criterion: a running `qless serve` session
/// picks up an ingest without restart — generation bumped in responses,
/// cached answers extended by a pass over ONLY the new rows, stats
/// reflecting the live row count, and `since_gen` ranking only newer
/// rows.
#[test]
fn running_server_picks_up_ingest_without_restart() {
    let (n0, add, k) = (14usize, 6usize, 64usize);
    let n_total = n0 + add;
    let etas = [0.6f32, 0.4];
    let p = Precision::new(4, Scheme::Absmax).unwrap();
    let dir = tmpdir("serve");
    let base = default_store_path(&dir, p);
    seeded_datastore(&base, p, n0, k, &etas, 42);
    let mono_path = dir.join("mono.qlds");
    let mono = seeded_datastore(&mono_path, p, n_total, k, &etas, 42);

    let server = Server::start(
        &base,
        ServeOpts {
            addr: "127.0.0.1:0".into(),
            batch_window_ms: 0,
            shard_rows: 5,
            workers: 2,
            ..Default::default()
        },
    )
    .unwrap();
    let mut c = Client::connect(server.addr()).unwrap();

    let task: Vec<FeatureMatrix> =
        (0..etas.len()).map(|ci| normal_features(2, k, 900 + ci as u64)).collect();
    let r0 = c.score(&task, 3, true).unwrap();
    assert_eq!(r0.generation, 0);
    assert_eq!(r0.scores.as_ref().unwrap().len(), n0);

    // ingest the monolithic fixture's tail rows mid-serve
    ingest_range(&dir, &[p], n0, n_total, n_total, k, &etas, 42, 4, 0);

    // the same query now covers the live store: generation bumped, the
    // cached prefix reused, and the producing pass read ONLY the new rows
    let r1 = c.score(&task, 3, true).unwrap();
    assert_eq!(r1.generation, 1, "served generation must bump without restart");
    let scores = r1.scores.as_ref().unwrap();
    assert_eq!(scores.len(), n_total);
    assert!(!r1.cached);
    assert_eq!(
        r1.pass.rows_read,
        (etas.len() * add) as u64,
        "extension must scan only the ingested rows"
    );
    let (want, _) = score_datastore_tasks(
        &mono,
        &[task.as_slice()],
        ScoreOpts { shard_rows: 5, ..Default::default() },
        None,
    )
    .unwrap();
    for (j, (a, b)) in want[0].iter().zip(scores).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "sample {j}: served vs monolithic scan");
    }

    let st = c.stats().unwrap();
    assert_eq!(st.generation, 1);
    assert_eq!(st.n_samples, n_total, "stats row count is live");
    assert_eq!(st.stats.reloads, 1);
    assert_eq!(st.stats.score_cache_extends, 1);

    // since_gen = 0: rank only rows newer than the base build
    let r2 = c.score_since(&task, add + 5, false, Some(0)).unwrap();
    assert!(r2.cached, "repeat task answers from the extended cache");
    assert_eq!(r2.top.len(), add, "only the ingested rows are rankable");
    assert!(r2.top.iter().all(|(i, _)| *i >= n0), "{:?}", r2.top);
    assert_eq!(r2.top, top_k_scored_since(&want[0], add + 5, n0));
    // nothing is newer than the current generation
    let r3 = c.score_since(&task, 3, false, Some(1)).unwrap();
    assert!(r3.top.is_empty());

    c.shutdown().unwrap();
    server.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// A crash that leaves one precision's segment torn must roll the whole
/// generation back — for every precision — and the directory must then
/// re-ingest cleanly. A torn tail is never served.
#[test]
fn torn_ingest_rolls_back_every_precision_together() {
    let (n0, add, k) = (8usize, 4usize, 24usize);
    let etas = [1.0f32];
    let grid =
        vec![Precision::new(4, Scheme::Absmax).unwrap(), Precision::new(1, Scheme::Sign).unwrap()];
    let dir = tmpdir("torn");
    for &p in &grid {
        seeded_datastore(&default_store_path(&dir, p), p, n0, k, &etas, 5);
    }
    ingest_range(&dir, &grid, n0, n0 + add, n0 + add, k, &etas, 5, 2, 0);

    // "crash": the 1-bit segment is lost after the manifest was published
    let onebit_seg = segment_store_path(&default_store_path(&dir, grid[1]), 1);
    std::fs::remove_file(&onebit_seg).unwrap();
    assert!(
        LiveStore::open(&default_store_path(&dir, grid[1])).is_err(),
        "a missing segment must not be served short"
    );

    let m = repair_run_dir(&dir, &grid).unwrap().unwrap();
    assert_eq!(m.generation, 0, "whole generation rolled back");
    assert_eq!(m.total_rows(), n0 as u64);
    let fourbit_seg = segment_store_path(&default_store_path(&dir, grid[0]), 1);
    assert!(!fourbit_seg.exists(), "the surviving precision's segment is dropped too");
    for &p in &grid {
        let live = LiveStore::open(&default_store_path(&dir, p)).unwrap();
        assert_eq!(live.n_rows(), n0);
        assert_eq!(live.generation(), 0);
    }

    // and the tail re-ingests cleanly after repair
    ingest_range(&dir, &grid, n0, n0 + add, n0 + add, k, &etas, 5, 3, 1);
    for &p in &grid {
        let live = LiveStore::open(&default_store_path(&dir, p)).unwrap();
        assert_eq!(live.n_rows(), n0 + add);
        assert_eq!(live.generation(), 1);
        // re-ingested bytes equal a monolithic build's tail
        let mono_path = dir.join(format!("mono2_{}b_{}.qlds", p.bits, p.scheme));
        let mono = seeded_datastore(&mono_path, p, n0 + add, k, &etas, 5);
        let mono_block = mono.load_checkpoint(0).unwrap();
        let seg_block = live.members()[1].ds.load_checkpoint(0).unwrap();
        for j in 0..add {
            assert_eq!(seg_block.row_bytes(j), mono_block.row_bytes(n0 + j), "{}", p.label());
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The shared manifest covers every precision of the run: repairing with
/// a precision *subset* must not truncate generations that are fully
/// intact, and a subset ingest is refused before any byte is written
/// (it would leave the uncovered precisions torn by construction).
#[test]
fn subset_repair_and_subset_ingest_respect_the_whole_run() {
    let (n0, add, k) = (6usize, 3usize, 16usize);
    let etas = [1.0f32];
    let p4 = Precision::new(4, Scheme::Absmax).unwrap();
    let p8 = Precision::new(8, Scheme::Absmax).unwrap();
    let dir = tmpdir("subset");
    for &p in &[p4, p8] {
        seeded_datastore(&default_store_path(&dir, p), p, n0, k, &etas, 2);
    }
    ingest_range(&dir, &[p4, p8], n0, n0 + add, n0 + add, k, &etas, 2, 2, 0);
    // repairing one precision still sees the whole run: nothing rolls back
    let m = repair_run_dir(&dir, &[p8]).unwrap().unwrap();
    assert_eq!(m.generation, 1, "subset repair must keep intact generations");
    for &p in &[p4, p8] {
        let live = LiveStore::open(&default_store_path(&dir, p)).unwrap();
        assert_eq!((live.generation(), live.n_rows()), (1, n0 + add), "{}", p.label());
    }
    // a subset ingest is refused up front
    let err = SegmentWriter::create(&dir, &[p4], 2, 0).unwrap_err();
    assert!(format!("{err:#}").contains("every precision"), "{err:#}");
    std::fs::remove_dir_all(&dir).ok();
}

/// Ingest refuses to append to a store whose geometry it cannot extend
/// safely, and `Datastore`-level reuse guards stay intact underneath the
/// live layer.
#[test]
fn ingest_guards_geometry() {
    let (n0, k) = (6usize, 16usize);
    let dir = tmpdir("guard");
    let p = Precision::new(8, Scheme::Absmax).unwrap();
    seeded_datastore(&default_store_path(&dir, p), p, n0, k, &[1.0, 0.5], 1);
    // a second precision with DIFFERENT geometry in the same dir: the
    // segment writer must refuse the mismatched pair
    let p2 = Precision::new(2, Scheme::Absmax).unwrap();
    seeded_datastore(&default_store_path(&dir, p2), p2, n0 + 1, k, &[1.0, 0.5], 1);
    let err = SegmentWriter::create(&dir, &[p, p2], 3, 0).unwrap_err();
    assert!(format!("{err:#}").contains("geometry"), "{err:#}");
    // the underlying per-file guard still catches plain geometry drift
    let ds = Datastore::open(&default_store_path(&dir, p)).unwrap();
    assert!(ds.matches_geometry(p, n0, k, 2));
    assert!(!ds.matches_geometry(p, n0 + 3, k, 2));
    std::fs::remove_dir_all(&dir).ok();
}
