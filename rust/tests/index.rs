//! Hamming-clustered IVF index acceptance suite — the index tentpole's
//! contract:
//!
//! * **full coverage == exhaustive scan**: with `nprobe = nclusters` the
//!   indexed top list is **byte-identical** (indices and f32 score bits)
//!   to the exhaustive scan, across bitwidth × scheme × shard size ×
//!   live generations — and regardless of whether the sidecar was built
//!   over the full store or built early and `refresh`ed over ingested
//!   (stale) rows;
//! * **recall@k is monotone** non-decreasing in `nprobe`, reaching
//!   exactly 1.0 at full coverage (a task's candidate set is the union
//!   of its top-`nprobe` clusters — a superset as `nprobe` grows, and
//!   any exhaustive winner inside the candidate set keeps its exact
//!   score);
//! * **paper-scale tradeoff**: on a 2048 × 512 clustered corpus the
//!   default `nprobe` keeps recall@k ≥ 0.9 while the row scan reads
//!   ≥ 4× fewer rows than the exhaustive pass (`ScanStats.rows_read` —
//!   row traffic, not centroid traffic, is the sub-linearity measure);
//! * **index × cascade composes**: at full coverage with a covering
//!   candidate pool the indexed cascade equals the plain cascade equals
//!   the exhaustive rerank-precision scan, byte for byte;
//! * **corrupt sidecars are never served**: truncated, torn, garbage,
//!   duplicated-row and wrong-geometry `.qidx` files are all rejected at
//!   open — the serving path (`open_for`) falls back to `None` and bumps
//!   `index_open_failures_total`, and `repair_run_dir` leaves a healthy
//!   sidecar in place.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use qless::datastore::{
    build_index, default_nprobe, default_store_path, index_path, reindex_store, repair_run_dir,
    DatastoreWriter, IndexBuildOpts, LiveStore, QuantIndex, SegmentWriter,
};
use qless::grads::FeatureMatrix;
use qless::influence::{
    cascade_live_tasks, index_cascade_live_tasks, index_scan_live_tasks, score_live_tasks,
    CascadeOpts, IndexOpts, ScoreOpts,
};
use qless::prop_assert;
use qless::quant::{Precision, Scheme};
use qless::select::top_k_scored;
use qless::util::obs::{self, Registry};
use qless::util::prop::{normal_features, run_prop, seeded_datastore};
use qless::util::Rng;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "qless_index_{tag}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Ingest rows `lo..hi` of the canonical seeded stream as one generation
/// (the same `SegmentWriter` loop `qless ingest` drives).
fn ingest_range(dir: &Path, ps: &[Precision], lo: usize, hi: usize, k: usize, ckpts: usize, seed: u64) {
    let mut sw = SegmentWriter::create(dir, ps, hi - lo, 0).unwrap();
    for ci in 0..ckpts {
        sw.begin_checkpoint().unwrap();
        let f = normal_features(hi, k, seed + ci as u64);
        sw.append_rows(&f.data[lo * k..hi * k]).unwrap();
        sw.end_checkpoint().unwrap();
    }
    sw.finalize().unwrap();
}

/// One validation task: per-checkpoint feature rows.
fn task(ckpts: usize, rows: usize, k: usize, seed: u64) -> Vec<FeatureMatrix> {
    (0..ckpts).map(|c| normal_features(rows, k, seed + 100 * c as u64)).collect()
}

/// Assert two top lists are byte-identical: same rows, same f32 bits.
fn assert_tops_identical(got: &[(usize, f32)], want: &[(usize, f32)], ctx: &str) -> Result<(), String> {
    if got.len() != want.len() {
        return Err(format!("{ctx}: {} vs {} entries", got.len(), want.len()));
    }
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        if g.0 != w.0 || g.1.to_bits() != w.1.to_bits() {
            return Err(format!(
                "{ctx}: entry {i}: got ({}, {:x}), want ({}, {:x})",
                g.0,
                g.1.to_bits(),
                w.0,
                w.1.to_bits()
            ));
        }
    }
    Ok(())
}

/// Recall@k of an indexed top list against the exhaustive top list.
fn recall(got: &[(usize, f32)], want: &[(usize, f32)]) -> f64 {
    let want_idx: std::collections::BTreeSet<usize> = want.iter().map(|(i, _)| *i).collect();
    let hit = got.iter().filter(|(i, _)| want_idx.contains(i)).count();
    hit as f64 / want.len().max(1) as f64
}

/// The CI smoke: an index at full coverage (`nprobe = nclusters`)
/// produces a digest (rows + score bits) identical to the exhaustive
/// scan. (`cargo test --test index smoke` runs exactly this.)
#[test]
fn smoke_full_coverage_index_equals_exhaustive_digest() {
    let dir = tmpdir("smoke");
    let (n, k) = (37usize, 64usize);
    let etas = [0.7f32, 0.3];
    let p1 = Precision::new(1, Scheme::Sign).unwrap();
    let path = default_store_path(&dir, p1);
    seeded_datastore(&path, p1, n, k, &etas, 1);
    let live = LiveStore::open(&path).unwrap();
    let idx = build_index(&live, &IndexBuildOpts { n_clusters: 5, max_iters: 0 }).unwrap();
    let t0 = task(2, 2, k, 500);
    let t1 = task(2, 3, k, 600);
    let tasks: Vec<&[FeatureMatrix]> = vec![&t0, &t1];
    let opts = IndexOpts { k: 6, nprobe: 5, scan: ScoreOpts { shard_rows: 7, ..Default::default() } };
    let out = index_scan_live_tasks(&live, &idx, &tasks, &opts).unwrap();
    assert_eq!(out.scanned_rows, n, "full coverage scans every row exactly once");
    let (scores, exh) = score_live_tasks(&live, &tasks, opts.scan).unwrap();
    for (t, top) in out.top.iter().enumerate() {
        let want = top_k_scored(&scores[t], 6);
        let digest_got: Vec<(usize, u32)> = top.iter().map(|(i, s)| (*i, s.to_bits())).collect();
        let digest_want: Vec<(usize, u32)> = want.iter().map(|(i, s)| (*i, s.to_bits())).collect();
        assert_eq!(digest_got, digest_want, "task {t}: indexed digest != exhaustive digest");
    }
    // full coverage reads every row once per checkpoint, like the
    // exhaustive pass — the savings exist only below full coverage
    assert_eq!(out.scan_pass.rows_read, exh.rows_read);
    std::fs::remove_file(index_path(&path)).ok();
    std::fs::remove_dir_all(&dir).ok();
}

/// Property: across store bitwidth × scheme × shard size × live
/// generations × cluster count × build timing (fresh rebuild vs early
/// build + stale refresh), full coverage is byte-identical to the
/// exhaustive scan.
#[test]
fn prop_full_coverage_index_is_byte_identical_to_exhaustive() {
    let grid = [
        Precision::new(1, Scheme::Sign).unwrap(),
        Precision::new(2, Scheme::Absmean).unwrap(),
        Precision::new(4, Scheme::Absmax).unwrap(),
        Precision::new(4, Scheme::Absmean).unwrap(),
        Precision::new(8, Scheme::Absmax).unwrap(),
        Precision::new(8, Scheme::Absmean).unwrap(),
        Precision::new(16, Scheme::Absmax).unwrap(),
    ];
    run_prop("index-exhaustive", 12, |g| {
        let n0 = 3 + g.usize_up_to(14);
        let add1 = g.rng.below(8);
        let add2 = if add1 > 0 { g.rng.below(5) } else { 0 };
        let n = n0 + add1 + add2;
        // k deliberately NOT a multiple of 8 half the time (packed rows
        // that end mid-byte → the padding-bit invariance is live)
        let k = 5 + g.usize_up_to(60);
        let ckpts = 1 + g.rng.below(2);
        let etas: Vec<f32> = (0..ckpts).map(|c| 0.9 - 0.4 * c as f32).collect();
        let seed = g.rng.below(1 << 20) as u64;
        let p = grid[g.rng.below(grid.len())];
        let dir = tmpdir("prop");
        let path = default_store_path(&dir, p);
        seeded_datastore(&path, p, n0, k, &etas, seed);
        // `stale_mode`: persist the sidecar BEFORE the ingests, so the
        // tail rows reach the index only through `refresh` — full
        // coverage must stay exact either way
        let stale_mode = (add1 > 0) && g.rng.below(2) == 0;
        let nclusters = 1 + g.rng.below(n0.min(9));
        let opts = IndexBuildOpts { n_clusters: nclusters, max_iters: 0 };
        if stale_mode {
            reindex_store(&path, &opts).map_err(|e| format!("reindex failed: {e:#}"))?;
        }
        if add1 > 0 {
            ingest_range(&dir, &[p], n0, n0 + add1, k, ckpts, seed);
        }
        if add2 > 0 {
            ingest_range(&dir, &[p], n0 + add1, n, k, ckpts, seed);
        }
        if !stale_mode {
            reindex_store(&path, &opts).map_err(|e| format!("reindex failed: {e:#}"))?;
        }
        let live = LiveStore::open(&path).unwrap();
        let idx = QuantIndex::open(&index_path(&path), &live)
            .map_err(|e| format!("sidecar open failed: {e:#}"))?;
        prop_assert!(
            idx.covered_rows() as usize == n,
            "index covers {} of {n} rows (stale_mode={stale_mode})",
            idx.covered_rows()
        );
        if stale_mode {
            prop_assert!(
                idx.stale_rows() as usize == add1 + add2,
                "early build must carry {} stale rows, has {}",
                add1 + add2,
                idx.stale_rows()
            );
        }
        let held: Vec<Vec<FeatureMatrix>> = (0..1 + g.rng.below(3))
            .map(|q| task(ckpts, 1 + g.rng.below(3), k, 7000 + 31 * q as u64))
            .collect();
        let tasks: Vec<&[FeatureMatrix]> = held.iter().map(|t| t.as_slice()).collect();
        let k_sel = 1 + g.rng.below(n);
        let scan = ScoreOpts { shard_rows: 1 + g.rng.below(n + 2), ..Default::default() };
        // nprobe at or past the cluster count → full coverage (clamped)
        let nprobe = idx.n_clusters() + g.rng.below(3);
        let out = index_scan_live_tasks(&live, &idx, &tasks, &IndexOpts { k: k_sel, nprobe, scan })
            .map_err(|e| format!("indexed scan failed: {e:#}"))?;
        prop_assert!(
            out.scanned_rows == n,
            "full coverage must scan all {n} rows (got {})",
            out.scanned_rows
        );
        let (scores, _) = score_live_tasks(&live, &tasks, scan).unwrap();
        for (t, top) in out.top.iter().enumerate() {
            let want = top_k_scored(&scores[t], k_sel);
            assert_tops_identical(
                top,
                &want,
                &format!(
                    "task {t} ({} store, n0={n0} add1={add1} add2={add2} k={k} k_sel={k_sel} \
                     nclusters={nclusters} stale_mode={stale_mode} shard_rows={})",
                    p.label(),
                    scan.shard_rows
                ),
            )?;
        }
        std::fs::remove_dir_all(&dir).ok();
        Ok(())
    });
}

/// Property: recall@k against the exhaustive top list never decreases as
/// `nprobe` grows, and is exactly 1.0 (byte-identical) at full coverage.
#[test]
fn prop_recall_is_monotone_in_nprobe() {
    run_prop("index-recall-monotone", 10, |g| {
        let n = 16 + g.usize_up_to(40);
        let k = 8 + g.usize_up_to(56);
        let ckpts = 1 + g.rng.below(2);
        let etas: Vec<f32> = (0..ckpts).map(|c| 0.8 - 0.3 * c as f32).collect();
        let seed = g.rng.below(1 << 20) as u64;
        let p1 = Precision::new(1, Scheme::Sign).unwrap();
        let dir = tmpdir("mono");
        let path = default_store_path(&dir, p1);
        seeded_datastore(&path, p1, n, k, &etas, seed);
        let live = LiveStore::open(&path).unwrap();
        let nclusters = 2 + g.rng.below(6);
        let idx =
            build_index(&live, &IndexBuildOpts { n_clusters: nclusters, max_iters: 0 }).unwrap();
        let t0 = task(ckpts, 2, k, 9000);
        let tasks: Vec<&[FeatureMatrix]> = vec![&t0];
        let k_sel = 1 + g.rng.below(6);
        let scan = ScoreOpts { shard_rows: 1 + g.rng.below(n), ..Default::default() };
        let (scores, _) = score_live_tasks(&live, &tasks, scan).unwrap();
        let want = top_k_scored(&scores[0], k_sel);
        let mut prev = -1.0f64;
        let mut prev_scanned = 0usize;
        for nprobe in 1..=idx.n_clusters() {
            let out = index_scan_live_tasks(&live, &idx, &tasks, &IndexOpts { k: k_sel, nprobe, scan })
                .map_err(|e| format!("indexed scan failed: {e:#}"))?;
            let r = recall(&out.top[0], &want);
            prop_assert!(
                r >= prev,
                "recall fell from {prev:.3} to {r:.3} when nprobe grew to {nprobe} \
                 (n={n} k={k} k_sel={k_sel} nclusters={})",
                idx.n_clusters()
            );
            prop_assert!(
                out.scanned_rows >= prev_scanned,
                "candidate set shrank ({prev_scanned} → {}) as nprobe grew to {nprobe}",
                out.scanned_rows
            );
            prev = r;
            prev_scanned = out.scanned_rows;
            if nprobe == idx.n_clusters() {
                prop_assert!(r == 1.0, "full coverage (nprobe={nprobe}) must recall 1.0, got {r:.3}");
                assert_tops_identical(&out.top[0], &want, "full-coverage top list")?;
            }
        }
        std::fs::remove_dir_all(&dir).ok();
        Ok(())
    });
}

/// Write a clustered 1-bit store: `centers` contiguous blobs of
/// `n / centers` rows each, row = its blob center + `noise`·N(0,1) per
/// checkpoint. Contiguous blobs line up with `build_index`'s
/// evenly-spaced seeding, so every blob deterministically receives
/// `nclusters / centers` seed centroids. Returns the per-checkpoint
/// center matrices (for drawing tasks near a center).
fn clustered_store(
    path: &Path,
    n: usize,
    k: usize,
    centers: usize,
    etas: &[f32],
    noise: f32,
    seed: u64,
) -> Vec<FeatureMatrix> {
    let p1 = Precision::new(1, Scheme::Sign).unwrap();
    let center_mats: Vec<FeatureMatrix> =
        (0..etas.len()).map(|ci| normal_features(centers, k, seed + 1000 * ci as u64)).collect();
    let mut w = DatastoreWriter::create(path, p1, n, k, etas.len()).unwrap();
    let per = n / centers;
    for (ci, &eta) in etas.iter().enumerate() {
        let mut rng = Rng::new(seed + 77 * ci as u64);
        w.begin_checkpoint(eta).unwrap();
        for i in 0..n {
            let c = (i / per).min(centers - 1);
            let row: Vec<f32> = center_mats[ci]
                .row(c)
                .iter()
                .map(|&v| v + noise * rng.normal() as f32)
                .collect();
            w.append_features(&row).unwrap();
        }
        w.end_checkpoint().unwrap();
    }
    w.finalize().unwrap();
    center_mats
}

/// A validation task drawn near blob `c`: per-checkpoint rows = the
/// checkpoint's center + small noise.
fn task_near_center(centers: &[FeatureMatrix], c: usize, rows: usize, seed: u64) -> Vec<FeatureMatrix> {
    centers
        .iter()
        .enumerate()
        .map(|(ci, m)| {
            let mut rng = Rng::new(seed + 13 * ci as u64);
            let k = m.k;
            let data: Vec<f32> = (0..rows * k)
                .map(|j| m.row(c)[j % k] + 0.1 * rng.normal() as f32)
                .collect();
            FeatureMatrix { n: rows, k, data }
        })
        .collect()
}

/// Paper-scale tradeoff (the PR's acceptance numbers, deterministic):
/// n=2048 × k=512, 16 contiguous blobs, 16 clusters (one evenly-spaced
/// seed lands at each blob start, so the clustering is balanced by
/// construction), **default** nprobe (16/8 = 2). Tasks concentrated
/// near one hot center — the regime a topically-focused validation set
/// produces — must keep recall@32 ≥ 0.9 while the row scan reads ≥ 4×
/// fewer rows than the exhaustive pass: each task probes its own blob's
/// cluster plus at most one other, so the candidate union is bounded by
/// 3 blobs = 384 rows < 2048/4 even in the worst case. Everything is
/// seeded; the assertion is exact, not statistical.
#[test]
fn index_quarters_row_traffic_at_paper_scale_with_high_recall() {
    let dir = tmpdir("paper");
    let (n, k, k_sel) = (2048usize, 512usize, 32usize);
    let etas = [0.6f32, 0.4];
    let p1 = Precision::new(1, Scheme::Sign).unwrap();
    let path = default_store_path(&dir, p1);
    let centers = clustered_store(&path, n, k, 16, &etas, 0.25, 42);
    let live = LiveStore::open(&path).unwrap();
    let idx = build_index(&live, &IndexBuildOpts { n_clusters: 16, max_iters: 0 }).unwrap();
    assert_eq!(default_nprobe(idx.n_clusters()), 2, "the default this test pins");
    let t0 = task_near_center(&centers, 5, 3, 9100);
    let t1 = task_near_center(&centers, 5, 2, 9200);
    let tasks: Vec<&[FeatureMatrix]> = vec![&t0, &t1];
    let scan = ScoreOpts { shard_rows: 256, ..Default::default() };
    // nprobe 0 → the default heuristic, exactly what `--nprobe` defaults
    // to through `effective_nprobe`
    let out = index_scan_live_tasks(&live, &idx, &tasks, &IndexOpts { k: k_sel, nprobe: 0, scan })
        .unwrap();
    let (scores, exh) = score_live_tasks(&live, &tasks, scan).unwrap();
    assert!(
        exh.rows_read >= 4 * out.scan_pass.rows_read,
        "row traffic: indexed scan read {} rows, exhaustive {} — less than 4× reduction",
        out.scan_pass.rows_read,
        exh.rows_read
    );
    assert!(out.scanned_rows * 4 <= n, "candidate union {} > n/4", out.scanned_rows);
    for (t, top) in out.top.iter().enumerate() {
        let want = top_k_scored(&scores[t], k_sel);
        let r = recall(top, &want);
        assert!(r >= 0.9, "task {t}: recall@{k_sel} = {r:.3} < 0.9 at the default nprobe");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Index × cascade composition: at full coverage with a covering
/// candidate pool, indexed cascade == plain cascade == exhaustive
/// rerank-precision scan, byte for byte.
#[test]
fn indexed_cascade_composes_exactly_at_full_coverage() {
    let dir = tmpdir("casc");
    let (n, k) = (29usize, 48usize);
    let etas = [0.7f32, 0.3];
    let p1 = Precision::new(1, Scheme::Sign).unwrap();
    let p8 = Precision::new(8, Scheme::Absmax).unwrap();
    let probe_path = default_store_path(&dir, p1);
    seeded_datastore(&probe_path, p1, n, k, &etas, 21);
    seeded_datastore(&default_store_path(&dir, p8), p8, n, k, &etas, 21);
    let probe_live = LiveStore::open(&probe_path).unwrap();
    let rerank_live = LiveStore::open(&default_store_path(&dir, p8)).unwrap();
    let idx = build_index(&probe_live, &IndexBuildOpts { n_clusters: 4, max_iters: 0 }).unwrap();
    let t0 = task(2, 2, k, 300);
    let tasks: Vec<&[FeatureMatrix]> = vec![&t0];
    // mult 6 · k 5 = 30 ≥ 29 rows → the pool covers the store
    let opts = CascadeOpts { k: 5, mult: 6, scan: ScoreOpts { shard_rows: 6, ..Default::default() } };
    let indexed = index_cascade_live_tasks(&probe_live, &rerank_live, &idx, &tasks, &opts, 4).unwrap();
    let plain = cascade_live_tasks(&probe_live, &rerank_live, &tasks, opts).unwrap();
    let (scores, _) = score_live_tasks(&rerank_live, &tasks, opts.scan).unwrap();
    let want = top_k_scored(&scores[0], 5);
    assert_tops_identical(&indexed.top[0], &want, "indexed cascade vs exhaustive").unwrap();
    assert_tops_identical(&indexed.top[0], &plain.top[0], "indexed cascade vs plain cascade")
        .unwrap();
    assert_eq!(indexed.reranked_rows, plain.reranked_rows);
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// fault injection: a corrupt sidecar is never served
// ---------------------------------------------------------------------------

/// Every corruption mode is rejected at open: the strict `open` errors
/// with the precise complaint, the serving path's `open_for` returns
/// `None` and bumps `index_open_failures_total` — an indexed query then
/// falls back to the exhaustive scan instead of serving a wrong grouping.
#[test]
fn corrupt_sidecars_are_rejected_and_never_served() {
    let dir = tmpdir("fault");
    let (n, k) = (23usize, 40usize);
    let p1 = Precision::new(1, Scheme::Sign).unwrap();
    let path = default_store_path(&dir, p1);
    seeded_datastore(&path, p1, n, k, &[0.8, 0.2], 7);
    let qidx = index_path(&path);
    reindex_store(&path, &IndexBuildOpts { n_clusters: 4, max_iters: 0 }).unwrap();
    let live = LiveStore::open(&path).unwrap();
    assert!(QuantIndex::open_for(&path, &live).is_some(), "healthy sidecar opens");
    let good = std::fs::read(&qidx).unwrap();

    // each case: (tag, corrupted bytes, substring the strict open must name)
    let mut garbage_magic = good.clone();
    garbage_magic[0..4].copy_from_slice(b"JUNK");
    let mut bad_version = good.clone();
    bad_version[4..8].copy_from_slice(&9999u32.to_le_bytes());
    let truncated = good[..good.len() / 2].to_vec();
    let torn_header = good[..20].to_vec();
    let mut padded = good.clone();
    padded.extend_from_slice(&[0u8; 16]);
    // duplicate a row id: the permutation check must catch it
    let mut dup_row = good.clone();
    let ids_at = dup_row.len() - n * 8;
    let first_id = dup_row[ids_at..ids_at + 8].to_vec();
    dup_row[ids_at + 8..ids_at + 16].copy_from_slice(&first_id);
    // a generation from the future: the run dir was rolled back under it
    let mut future_gen = good.clone();
    future_gen[32..40].copy_from_slice(&u64::MAX.to_le_bytes());
    let cases: Vec<(&str, Vec<u8>, &str)> = vec![
        ("garbage magic", garbage_magic, "magic"),
        ("bad version", bad_version, "version"),
        ("truncated", truncated, "bytes"),
        ("torn header", torn_header, "truncated"),
        ("padded tail", padded, "bytes"),
        ("duplicate row id", dup_row, "twice"),
        ("future generation", future_gen, "generation"),
    ];
    let reg = Arc::new(Registry::new());
    obs::with_registry(reg.clone(), || {
        for (tag, bytes, msg) in &cases {
            std::fs::write(&qidx, bytes).unwrap();
            let err = format!("{:#}", QuantIndex::open(&qidx, &live).unwrap_err());
            assert!(err.contains(msg), "{tag}: expected {msg:?} in {err}");
            assert!(
                QuantIndex::open_for(&path, &live).is_none(),
                "{tag}: serving open must refuse the sidecar"
            );
        }
    });
    let snap = reg.snapshot();
    assert_eq!(
        snap.counters.get("index_open_failures_total").copied().unwrap_or(0),
        cases.len() as u64,
        "every rejected sidecar must tick the failure counter"
    );
    // geometry mismatch: a sidecar built for a DIFFERENT store (other k)
    let dir2 = tmpdir("fault2");
    let path2 = default_store_path(&dir2, p1);
    seeded_datastore(&path2, p1, n, 48, &[0.8, 0.2], 7);
    reindex_store(&path2, &IndexBuildOpts { n_clusters: 4, max_iters: 0 }).unwrap();
    std::fs::copy(index_path(&path2), &qidx).unwrap();
    let err = format!("{:#}", QuantIndex::open(&qidx, &live).unwrap_err());
    assert!(err.contains("k"), "geometry mismatch must name k: {err}");
    assert!(QuantIndex::open_for(&path, &live).is_none());
    // a missing sidecar is simply None — no warning, no counter
    std::fs::remove_file(&qidx).unwrap();
    assert!(QuantIndex::open_for(&path, &live).is_none());
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&dir2).ok();
}

/// `repair_run_dir` (the crash-recovery sweep every build/ingest runs
/// first) must leave a healthy sidecar in place: the index is derived
/// state with its own open-time validation, not a crash leftover.
#[test]
fn repair_run_dir_leaves_the_sidecar_alone() {
    let dir = tmpdir("repair");
    let (n0, add, k) = (11usize, 4usize, 32usize);
    let p1 = Precision::new(1, Scheme::Sign).unwrap();
    let path = default_store_path(&dir, p1);
    seeded_datastore(&path, p1, n0, k, &[1.0], 3);
    ingest_range(&dir, &[p1], n0, n0 + add, k, 1, 3);
    reindex_store(&path, &IndexBuildOpts { n_clusters: 3, max_iters: 0 }).unwrap();
    let qidx = index_path(&path);
    assert!(qidx.exists());
    let before = std::fs::read(&qidx).unwrap();
    let m = repair_run_dir(&dir, &[p1]).unwrap();
    assert!(m.is_some(), "the ingested run dir has a manifest");
    assert!(qidx.exists(), "repair must not delete the sidecar");
    assert_eq!(std::fs::read(&qidx).unwrap(), before, "repair must not rewrite the sidecar");
    let live = LiveStore::open(&path).unwrap();
    let idx = QuantIndex::open(&qidx, &live).unwrap();
    assert_eq!(idx.covered_rows() as usize, n0 + add);
    std::fs::remove_dir_all(&dir).ok();
}
