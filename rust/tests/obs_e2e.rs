//! End-to-end observability acceptance suite — the tracing + metrics
//! tentpole's contract, driven over real sockets:
//!
//! * a traced `score` against a single server answers with the server's
//!   own direct `timing` spans (handler root + queue-wait child), and
//!   the scrape right after it shows the pass in every layer's
//!   counters — rows scanned per bitwidth, cache traffic, the `score_us`
//!   latency histogram — consistent with the reply and the `stats` verb;
//! * a traced cascade against a 2-worker coordinator yields **one
//!   stitched tree**: a `coordinator.score` root, one wave span per
//!   cascade stage, one rpc span per sub-query, and the workers' own
//!   spans re-homed under their rpc spans — every parent resolving
//!   inside the reply's span array;
//! * the scraped Prometheus text carries the same metric families the
//!   JSON snapshot does.
//!
//! The global registry and span ring are process-wide and the tests in
//! this binary run in parallel, so counter assertions here are `>=`,
//! never exact — `tests/cascade.rs` proves exactness under an isolated
//! per-thread registry.

use std::path::PathBuf;

use qless::datastore::default_store_path;
use qless::grads::FeatureMatrix;
use qless::quant::{Precision, Scheme};
use qless::service::{Client, Coordinator, CoordinatorOpts, ServeOpts, Server, TraceField};
use qless::util::obs;
use qless::util::obs::SpanRecord;
use qless::util::prop::{normal_features, seeded_datastore};

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "qless_obs_{tag}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn task(ckpts: usize, rows: usize, k: usize, seed: u64) -> Vec<FeatureMatrix> {
    (0..ckpts).map(|c| normal_features(rows, k, seed + 100 * c as u64)).collect()
}

/// Every span's parent must be 0 or another span in the same array — a
/// dangling parent means the stitcher lost part of the tree.
fn assert_parents_resolve(spans: &[SpanRecord], ctx: &str) {
    for s in spans {
        assert!(
            s.parent == 0 || spans.iter().any(|p| p.id == s.parent),
            "{ctx}: span '{}' (id {:#x}) has dangling parent {:#x}\nall: {spans:#?}",
            s.name,
            s.id,
            s.parent
        );
    }
}

/// The CI obs smoke, single-node half: serve → traced score → scrape →
/// nonzero counters, a consistent histogram, and the server's direct
/// timing spans on the reply.
#[test]
fn traced_score_then_scrape_is_consistent_on_a_single_server() {
    obs::set_tracing(true);
    let dir = tmpdir("single");
    let (n, k) = (23usize, 64usize);
    let p8 = Precision::new(8, Scheme::Absmax).unwrap();
    let path = default_store_path(&dir, p8);
    seeded_datastore(&path, p8, n, k, &[0.7, 0.3], 9);
    let server = Server::start(
        &path,
        ServeOpts { addr: "127.0.0.1:0".into(), batch_window_ms: 0, ..Default::default() },
    )
    .unwrap();
    let mut c = Client::connect(server.addr()).unwrap();
    c.set_trace(Some(TraceField { id: 0x51e55, parent: 0 }));
    let val = task(2, 2, k, 77);
    let r = c.score(&val, 4, true).unwrap();
    assert_eq!(r.scores.as_ref().unwrap().len(), n);

    // the reply carries the server's direct measurements: a handler root
    // and its queue-wait child, properly nested
    let timing = r.timing.expect("traced request answers with timing");
    let root = timing.iter().find(|s| s.name == "server.score").expect("handler root span");
    assert_eq!(root.parent, 0, "client sent parent 0, the root keeps it");
    let wait = timing.iter().find(|s| s.name == "server.wait").expect("queue-wait span");
    assert_eq!(wait.parent, root.id, "wait nests under the handler root");
    assert!(wait.dur_us <= root.dur_us, "a nested span cannot outlast its parent");
    assert_parents_resolve(&timing, "single-server timing");

    // untraced requests stay exactly as cheap as before: no timing field
    c.set_trace(None);
    assert!(c.score(&val, 4, false).unwrap().timing.is_none());

    // the scrape right after is consistent with what the queries did
    let st = c.stats().unwrap();
    let m = c.metrics(true, true).unwrap();
    let counter = |name: &str| m.snapshot.counters.get(name).copied().unwrap_or(0);
    // two queries × 2 checkpoints × n rows flowed through the 8-bit scan
    // seam (other tests in this binary may add more — hence >=)
    assert!(
        counter("scan_rows_total{bits=\"8\"}") >= (2 * 2 * n) as u64,
        "scan counter missed the served passes: {:?}",
        m.snapshot.counters
    );
    assert!(counter("scan_bytes_total{bits=\"8\"}") > 0);
    assert!(
        counter("score_cache_misses_total") >= 2,
        "both cold queries must be counted as score-cache misses"
    );
    assert!(st.stats.rows_scored >= (2 * n) as u64, "stats verb agrees rows were scored");
    let h = m.snapshot.histos.get("score_us").expect("score_us histogram exists");
    assert!(h.count >= 2, "both scores observed: {h:?}");
    assert!(h.sum > 0 && h.quantile(0.99) >= h.quantile(0.5));
    // Prometheus text carries the same families
    let text = m.prometheus.expect("prometheus:true returns the text");
    assert!(text.contains("# TYPE qless_scan_rows_total counter"), "{text}");
    assert!(text.contains("qless_score_us_bucket"), "{text}");
    assert!(text.contains("qless_session_rows"), "{text}");
    // the ring kept the handler spans (tracing is on in this binary)
    let traces = m.traces.expect("traces:true returns the ring");
    assert!(
        traces.iter().any(|s| s.name == "server.score" && s.trace == 0x51e55),
        "the traced query's handler span must be in the ring"
    );

    c.shutdown().unwrap();
    server.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// The CI obs smoke, distributed half: a traced 1→8-bit cascade against
/// a 2-worker coordinator answers with ONE stitched tree — root, wave
/// spans, rpc spans, and the workers' own handler spans re-homed under
/// their rpcs — every parent resolving inside the reply.
#[test]
fn traced_cascade_yields_one_stitched_tree_across_the_fleet() {
    obs::set_tracing(true);
    let dir = tmpdir("fleet");
    let (n, k) = (29usize, 64usize);
    let etas = [0.6f32, 0.4];
    let p1 = Precision::new(1, Scheme::Sign).unwrap();
    let p8 = Precision::new(8, Scheme::Absmax).unwrap();
    let probe_path = default_store_path(&dir, p1);
    seeded_datastore(&probe_path, p1, n, k, &etas, 3);
    seeded_datastore(&default_store_path(&dir, p8), p8, n, k, &etas, 3);

    let co = Coordinator::start_local(
        &probe_path,
        2,
        ServeOpts { addr: "127.0.0.1:0".into(), batch_window_ms: 0, shard_rows: 7, ..Default::default() },
        CoordinatorOpts { addr: "127.0.0.1:0".into(), ..Default::default() },
    )
    .unwrap();
    let mut c = Client::connect(co.addr()).unwrap();
    c.set_trace(Some(TraceField { id: 0xcafe, parent: 0 }));
    let val = task(2, 2, k, 17);
    let r = c.score_cascade(&val, 4, 1, 8, 2).unwrap();
    assert_eq!(r.top.len(), 4, "the traced cascade still answers");

    let spans = r.timing.expect("traced cascade answers with the stitched tree");
    assert_parents_resolve(&spans, "stitched cascade tree");
    let roots: Vec<&SpanRecord> = spans.iter().filter(|s| s.name == "coordinator.score").collect();
    assert_eq!(roots.len(), 1, "exactly one root: {spans:#?}");
    let root = roots[0];
    assert_eq!(root.parent, 0);
    // one wave span per cascade stage, both children of the root
    for wave in ["wave.probe", "wave.rerank"] {
        let w = spans.iter().find(|s| s.name == wave).unwrap_or_else(|| {
            panic!("missing {wave} in stitched tree: {spans:#?}")
        });
        assert_eq!(w.parent, root.id, "{wave} hangs off the root");
    }
    // 2 workers × probe wave → at least two rpc.probe spans, each under
    // the probe wave; the rerank wave issued at least one rpc.rerank
    let probe_wave = spans.iter().find(|s| s.name == "wave.probe").unwrap();
    let rerank_wave = spans.iter().find(|s| s.name == "wave.rerank").unwrap();
    let rpc_probe: Vec<&SpanRecord> = spans.iter().filter(|s| s.name == "rpc.probe").collect();
    let rpc_rerank: Vec<&SpanRecord> = spans.iter().filter(|s| s.name == "rpc.rerank").collect();
    assert!(rpc_probe.len() >= 2, "2 workers → 2+ probe rpcs: {spans:#?}");
    assert!(!rpc_rerank.is_empty(), "rerank wave issued rpcs: {spans:#?}");
    assert!(rpc_probe.iter().all(|s| s.parent == probe_wave.id));
    assert!(rpc_rerank.iter().all(|s| s.parent == rerank_wave.id));
    // the workers' own handler spans were absorbed and re-homed under
    // rpc spans — the tree spans process boundaries
    let absorbed: Vec<&SpanRecord> = spans.iter().filter(|s| s.name == "server.score").collect();
    assert!(
        absorbed.len() >= rpc_probe.len(),
        "every answered rpc absorbs the worker's handler span: {spans:#?}"
    );
    let rpc_ids: Vec<u64> =
        rpc_probe.iter().chain(&rpc_rerank).map(|s| s.id).collect();
    assert!(
        absorbed.iter().all(|s| rpc_ids.contains(&s.parent)),
        "absorbed worker spans re-home under their rpc spans: {spans:#?}"
    );

    // a fleet scrape with traces merges the coordinator's ring and both
    // workers' rings; the coordinator's stitched spans are in there
    let m = c.metrics(true, false).unwrap();
    assert!(
        m.snapshot.counters.get("scan_rows_total{bits=\"1\"}").copied().unwrap_or(0)
            >= (2 * n) as u64,
        "fleet-merged scrape sees the workers' probe scans: {:?}",
        m.snapshot.counters
    );
    let ring = m.traces.expect("traces:true returns the merged ring");
    assert!(
        ring.iter().any(|s| s.name == "coordinator.score" && s.trace == 0xcafe),
        "the stitched root is in the coordinator's ring"
    );

    c.shutdown().unwrap();
    co.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}
