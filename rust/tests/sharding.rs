//! Sharded-streaming equivalence suite.
//!
//! The streaming influence engine's contract: scanning a datastore in
//! shards (any shard size, any memory budget) produces scores
//! **bit-identical** to the old whole-block scan, at every bitwidth.
//! Property-tested over random shapes, shard sizes (including sizes that
//! do not divide n), η weights and checkpoint counts.
//!
//! Also pins the NaN propagation contract: a NaN gradient is rejected
//! loudly at quantization/write time, never laundered through
//! quantize → pack → score into the far-away NaN panic in `select::topk`.

use std::path::PathBuf;

use qless::datastore::{Datastore, DatastoreWriter};
use qless::grads::FeatureMatrix;
use qless::influence::native::{scores_rows, ValFeatures};
use qless::influence::{score_datastore, ScoreOpts};
use qless::prop_assert;
use qless::quant::{Precision, Scheme};
use qless::select::select_top_frac;
use qless::util::prop::{normal_features as feats, run_prop, seeded_datastore};

fn tmpfile(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "qless_shardtest_{tag}_{}_{:?}.qlds",
        std::process::id(),
        std::thread::current().id()
    ))
}

fn build_store(
    tag: &str,
    bits: u8,
    n: usize,
    k: usize,
    etas: &[f32],
    seed: u64,
) -> (Datastore, PathBuf) {
    let scheme = if bits == 1 { Scheme::Sign } else { Scheme::Absmax };
    let p = Precision::new(bits, scheme).unwrap();
    let path = tmpfile(tag);
    (seeded_datastore(&path, p, n, k, etas, seed), path)
}

/// The old whole-block scan, reconstructed from its parts: load each
/// checkpoint block fully, score with the per-precision kernel dispatch
/// (the same `scores_rows` the streamed scan uses — popcount at 1-bit,
/// the integer engine at 2/4/8-bit, f32 at 16-bit), accumulate η-weighted
/// totals in checkpoint order.
fn whole_block_scores(ds: &Datastore, val_per_ckpt: &[FeatureMatrix]) -> Vec<f32> {
    let mut total = vec![0f32; ds.n_samples()];
    for ci in 0..ds.n_checkpoints() {
        let block = ds.load_checkpoint(ci).unwrap();
        let val = ValFeatures::prepare(&val_per_ckpt[ci], block.precision);
        let scores = scores_rows(&block.rows(), &val);
        for (t, s) in total.iter_mut().zip(&scores) {
            *t += block.eta * s;
        }
    }
    total
}

#[test]
fn prop_sharded_scores_equal_whole_block_exactly() {
    let bitwidths = [16u8, 8, 4, 2, 1];
    run_prop("sharded-equals-block", 40, |g| {
        let n = 2 + g.usize_up_to(40);
        let k = 8 * (1 + g.usize_up_to(24)); // up to 192 dims
        let bits = bitwidths[g.rng.below(bitwidths.len())];
        let ckpts = 1 + g.rng.below(3);
        let etas: Vec<f32> = (0..ckpts).map(|c| 0.1 + 0.3 * c as f32).collect();
        let seed = g.rng.below(1 << 20) as u64;
        let (ds, path) = build_store(&format!("prop{bits}"), bits, n, k, &etas, seed);
        let vals: Vec<FeatureMatrix> =
            (0..ckpts).map(|c| feats(1 + c, k, seed + 1000 + c as u64)).collect();
        let expect = whole_block_scores(&ds, &vals);

        // shard sizes: dividing, non-dividing, degenerate, oversized
        let shard_sizes = [1usize, 2, n / 2 + 1, n - 1, n, n + 7];
        for &shard_rows in &shard_sizes {
            if shard_rows == 0 {
                continue;
            }
            let got = score_datastore(
                &ds,
                &vals,
                ScoreOpts { shard_rows, ..Default::default() },
                None,
            )
            .map_err(|e| e.to_string())?;
            prop_assert!(
                got == expect,
                "bits={bits} n={n} k={k} ckpts={ckpts} shard_rows={shard_rows}: \
                 streamed scores differ from whole-block scan"
            );
        }
        std::fs::remove_file(path).ok();
        Ok(())
    });
}

#[test]
fn tight_memory_budget_matches_whole_block() {
    // 1 MiB budget on a store whose 16-bit block is ~3 MiB: several shards.
    let (n, k) = (3000usize, 512usize);
    for bits in [16u8, 1] {
        let (ds, path) = build_store(&format!("budget{bits}"), bits, n, k, &[0.7, 0.3], 9);
        let vals = vec![feats(4, k, 100), feats(4, k, 101)];
        let expect = whole_block_scores(&ds, &vals);
        let got = score_datastore(
            &ds,
            &vals,
            ScoreOpts { shard_rows: 0, mem_budget_mb: 1, ..Default::default() },
            None,
        )
        .unwrap();
        assert_eq!(got, expect, "bits {bits}");
        // the budget really is smaller than the block it replaced
        let rows = ds.rows_per_shard(0, 1);
        assert!(
            (rows as u64) * ds.header.resident_row_bytes() <= 1 << 20,
            "shard resident bytes exceed the 1 MiB budget"
        );
        if bits == 16 {
            // the block (~3 MiB) no longer fits the budget: the scan really
            // streamed it in several shards
            assert!(rows < n, "16-bit scan did not shard under a 1 MiB budget");
        }
        std::fs::remove_file(path).ok();
    }
}

#[test]
fn nan_is_rejected_at_quantization_not_at_select() {
    // clean path: quantize → pack → score → select works end to end
    let (n, k) = (40usize, 64usize);
    let (ds, path) = build_store("nanclean", 1, n, k, &[1.0], 77);
    let vals = vec![feats(3, k, 78)];
    let scores = score_datastore(&ds, &vals, ScoreOpts::default(), None).unwrap();
    assert!(scores.iter().all(|s| s.is_finite()));
    let sel = select_top_frac(&scores, 0.10); // would panic on any NaN
    assert_eq!(sel.len(), 4);
    std::fs::remove_file(path).ok();

    // poisoned path: the NaN must be caught at write/quantize time with a
    // clear error — long before a score or the topk NaN panic exists
    let p = Precision::new(1, Scheme::Sign).unwrap();
    let path = tmpfile("nanpoison");
    let mut w = DatastoreWriter::create(&path, p, 2, k, 1).unwrap();
    w.begin_checkpoint(1.0).unwrap();
    let mut row = vec![0.5f32; k];
    row[17] = f32::NAN;
    let err = w.append_features(&row).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("non-finite"), "unexpected error: {msg}");
    assert!(msg.contains("quantiz"), "error should name the quantization stage: {msg}");
    std::fs::remove_file(path).ok();
}
