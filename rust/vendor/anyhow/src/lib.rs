//! Minimal offline re-implementation of the `anyhow` API surface used by
//! the `qless` crate (the build environment has no crates.io access).
//!
//! Provides: [`Error`], [`Result`], the [`Context`] extension trait for
//! `Result`/`Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//! Display semantics follow upstream anyhow: `{}` shows the outermost
//! message, `{:#}` joins the whole context chain with `: `, and `{:?}`
//! renders the chain as a `Caused by:` list.

use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error carrying a chain of context messages. `chain[0]` is the
/// outermost (most recently attached) message.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Attach an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the chain of messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like upstream anyhow, `Error` deliberately does NOT implement
// `std::error::Error`: that would conflict with this blanket conversion,
// which is what makes `?` work on any std-error type.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_outermost_only() {
        let e: Error = io_err().into();
        let e = e.context("opening config");
        assert_eq!(e.to_string(), "opening config");
        assert!(format!("{e:#}").contains("missing"));
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn question_mark_and_macros() {
        fn inner() -> Result<()> {
            let _ = std::fs::metadata("/definitely/not/a/path/qless")?;
            Ok(())
        }
        assert!(inner().is_err());
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert!(check(-1).is_err());
        assert!(check(101).unwrap_err().to_string().contains("too big"));
        assert_eq!(check(5).unwrap(), 5);
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.context("empty").unwrap_err();
        assert_eq!(e.to_string(), "empty");
    }
}
