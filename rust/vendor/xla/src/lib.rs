//! Gated stub of the `xla` (PJRT) bindings.
//!
//! The offline build environment carries no XLA shared library, so this
//! crate provides the exact API surface `qless::runtime` compiles against,
//! with every entry point that would touch PJRT returning a clear
//! "backend unavailable" error. The `qless` test-suite and benches check
//! for built artifacts (`artifacts/manifest.json`) before constructing a
//! runtime, so on a stub build they skip gracefully instead of failing.
//!
//! Swapping in the real bindings is a one-line Cargo change: point the
//! `xla` dependency at the actual crate; no `qless` source changes needed.

use std::fmt;

/// Error type mirroring the real crate's: a plain message.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: XLA/PJRT backend not available in this build \
             (offline stub — link the real `xla` crate to enable it)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a literal can report.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    F64,
    S8,
    S32,
    S64,
    U8,
    Pred,
}

/// Primitive types accepted by [`Literal::convert`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    F64,
    S8,
    S32,
    S64,
    U8,
    Pred,
}

/// Marker trait for host element types the bindings understand.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i8 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// Host-side literal. The stub carries no data; any operation that would
/// read device output errors first, so values are never observed.
#[derive(Debug)]
pub struct Literal(());

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal(())
    }

    pub fn scalar<T: NativeType>(_v: T) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal(()))
    }

    pub fn ty(&self) -> Result<ElementType> {
        Err(Error::unavailable("Literal::ty"))
    }

    pub fn convert(&self, _ty: PrimitiveType) -> Result<Literal> {
        Err(Error::unavailable("Literal::convert"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module proto.
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation built from a module proto.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device-resident buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Argument kinds accepted by [`PjRtLoadedExecutable::execute`] /
/// [`PjRtLoadedExecutable::execute_b`].
pub trait ExecuteArg {}
impl ExecuteArg for Literal {}
impl ExecuteArg for &PjRtBuffer {}

/// A compiled, device-loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T: ExecuteArg>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }

    pub fn execute_b<T: ExecuteArg>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable("PjRtClient::buffer_from_host_buffer"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_errors_are_loud_and_clear() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("not available"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(Literal::vec1(&[1.0f32]).to_vec::<f32>().is_err());
    }

    #[test]
    fn host_side_constructors_succeed() {
        // Literal construction and reshape are host-only in the real crate;
        // the stub keeps them infallible so argument marshalling code paths
        // are exercised up to the first device call.
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[2, 1]).is_ok());
        let _ = Literal::scalar(3i32);
    }
}
