//! Quickstart: the QLESS public API in ~60 seconds.
//!
//! Generates a small synthetic instruction corpus, extracts gradient
//! features at one (untrained) checkpoint, builds 16-bit and 1-bit gradient
//! datastores, scores influence against a SynQA validation split, and shows
//! the paper's headline trade: ~16× smaller storage, same selection.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use anyhow::Result;
use qless::config::Config;
use qless::eval::Benchmark;
use qless::pipeline::Pipeline;
use qless::quant::{Precision, Scheme};
use qless::select::{select_top_frac, SourceDistribution};
use qless::util::table::human_bytes;

fn main() -> Result<()> {
    let mut cfg = Config::default();
    cfg.model = "tiny".into();
    cfg.corpus_size = 600;
    cfg.warmup_epochs = 1;
    cfg.val_per_task = 8;
    cfg.run_dir = "runs/quickstart".into();
    let mut pipe = Pipeline::new(cfg)?;

    println!("corpus: {} samples across 4 sources", pipe.corpus.len());
    for (src, n) in qless::corpus::source_counts(&pipe.corpus.samples) {
        println!("  {src:10} {n}");
    }

    // LESS baseline (16-bit) vs QLESS 1-bit datastores over the same
    // features — built in ONE streamed extraction pass (the `--bits 16,1`
    // sweep), never materializing the fp32 feature matrix.
    let mut stores = pipe.build_datastores(&[
        Precision::new(16, Scheme::Absmax)?,
        Precision::new(1, Scheme::Sign)?,
    ])?;
    let (ds1, b1) = stores.remove(1);
    let (ds16, b16) = stores.remove(0);
    println!("\ndatastore  16-bit: {:>12}", human_bytes(b16));
    println!(
        "datastore   1-bit: {:>12}  ({:.1}x smaller)",
        human_bytes(b1),
        b16 as f64 / b1 as f64
    );

    // Influence-score the corpus against SynQA validation gradients.
    // The scan streams each checkpoint block in shards under the config's
    // memory budget (`--mem-budget-mb` / `--shard-rows`); shard size is an
    // implementation knob, not a semantic — scores are bit-identical.
    let rows = ds1.rows_per_shard(pipe.cfg.shard_rows, pipe.cfg.mem_budget_mb);
    println!(
        "\nscan: {} rows/shard, {} resident (block would be {})",
        rows,
        human_bytes(rows as u64 * ds1.header.resident_row_bytes()),
        human_bytes(ds1.header.block_bytes())
    );
    let s16 = pipe.influence_scores(&ds16, Benchmark::SynQA)?;
    let s1 = pipe.influence_scores(&ds1, Benchmark::SynQA)?;
    let top16 = select_top_frac(&s16, 0.05);
    let top1 = select_top_frac(&s1, 0.05);
    let overlap = top1.iter().filter(|i| top16.contains(i)).count();
    println!("\ntop-5% selection (SynQA target):");
    println!("  16-bit: {}", SourceDistribution::of(&pipe.corpus.samples, &top16).render());
    println!("   1-bit: {}", SourceDistribution::of(&pipe.corpus.samples, &top1).render());
    println!("  overlap: {overlap}/{} selections agree", top16.len());

    println!("\nhighest-influence samples (1-bit store):");
    for &i in top1.iter().take(3) {
        let s = &pipe.corpus.samples[i];
        println!("  [{:+.4}] ({}) {} → {}", s1[i], s.source, s.prompt, s.answer);
    }
    println!("\nnext: cargo run --release --example full_pipeline");
    Ok(())
}
