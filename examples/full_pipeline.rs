//! End-to-end validation driver (DESIGN.md §5): the full QLESS pipeline on
//! a real (synthetic) instruction-tuning workload, exercising every layer:
//!
//!   L2/L1 AOT graphs → pretrain → warmup (loss curve) → per-checkpoint
//!   gradient features → 16-bit + 1-bit datastores → influence scoring →
//!   top-5% selection → fine-tune → 3-benchmark eval,
//!
//! and reports the paper's headline: QLESS 1-bit ≈ LESS 16-bit ≈/> random
//! 5%, at ~16× less gradient storage. Results are recorded in
//! EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example full_pipeline [-- --fast]`

use anyhow::Result;
use qless::config::Config;
use qless::pipeline::{Method, Pipeline};
use qless::quant::{Precision, Scheme};
use qless::util::table::{human_bytes, pct, Table};

fn main() -> Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let mut cfg = Config::default();
    if fast {
        cfg.model = "tiny".into();
        cfg.corpus_size = 1200;
        cfg.warmup_epochs = 2;
        cfg.finetune_epochs = 3;
        cfg.eval_per_task = 48;
        cfg.val_per_task = 16;
    } else {
        cfg.model = "small".into();
        cfg.corpus_size = 4000;
        cfg.warmup_epochs = 4;
        cfg.finetune_epochs = 4;
        cfg.eval_per_task = 96;
        cfg.val_per_task = 32;
    }
    cfg.run_dir = format!("runs/full_pipeline_{}", cfg.model);
    let t0 = std::time::Instant::now();
    let mut pipe = Pipeline::new(cfg.clone())?;

    // Warmup: print the loss curve (proves the training loop works E2E).
    let set = pipe.warmup()?;
    println!("\nwarmup checkpoints: {} (η per epoch: {:?})",
        set.checkpoints.len(),
        set.checkpoints.iter().map(|c| format!("{:.2e}", c.eta)).collect::<Vec<_>>(),
    );

    let mut table = Table::new(
        &format!("full pipeline — SimLM-{} on {} samples", cfg.model, cfg.corpus_size),
        &["Data Selection", "Storage", "SynQA", "SynMC", "SynArith", "Avg"],
    );
    let methods = [
        Method::RandomFrac,
        Method::Qless(Precision::new(16, Scheme::Absmax)?), // LESS
        Method::Qless(Precision::new(1, Scheme::Sign)?),    // QLESS 1-bit
    ];
    let mut storages = Vec::new();
    for m in methods {
        let r = pipe.run_method(m)?;
        if r.storage_bytes > 0 {
            storages.push(r.storage_bytes);
        }
        table.row(vec![
            r.label.clone(),
            if r.storage_bytes > 0 { human_bytes(r.storage_bytes) } else { "-".into() },
            pct(r.scores["SynQA"]),
            pct(r.scores["SynMC"]),
            pct(r.scores["SynArith"]),
            pct(r.average),
        ]);
        for (bench, curve) in &r.loss_curves {
            println!("  {} fine-tune loss curve [{bench}]: {:?}",
                r.label,
                curve.iter().map(|l| format!("{l:.3}")).collect::<Vec<_>>());
        }
    }
    for col in 2..6 {
        table.mark_best(col, true);
    }
    println!("\n{}", table.render());
    if storages.len() == 2 {
        println!(
            "headline: 1-bit datastore is {:.1}x smaller than 16-bit ({} vs {})",
            storages[0] as f64 / storages[1] as f64,
            human_bytes(storages[0]),
            human_bytes(storages[1])
        );
    }
    println!("total wall time: {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
