//! Scheme ablation walk-through (paper §5 / Fig. 3 / Table 3 intuition):
//! quantize *real* extracted gradient features with absmax, absmean and
//! sign at each bit width, and show (a) zero-bin occupancy, (b) selection
//! agreement with the 16-bit reference, per benchmark.
//!
//! Run: `cargo run --release --example scheme_ablation`

use anyhow::Result;
use qless::config::Config;
use qless::eval::Benchmark;
use qless::pipeline::Pipeline;
use qless::quant::{BinHistogram, Precision, Scheme};
use qless::select::select_top_frac;
use qless::util::table::Table;

fn main() -> Result<()> {
    let mut cfg = Config::default();
    cfg.model = "tiny".into();
    cfg.corpus_size = 800;
    cfg.warmup_epochs = 2;
    cfg.val_per_task = 12;
    cfg.run_dir = "runs/scheme_ablation".into();
    let mut pipe = Pipeline::new(cfg)?;

    // (a) zero-bin occupancy on real features (Fig. 3). Dense features are
    // the explicit small-run opt-in (800 samples here); datastore builds
    // stream instead and never materialize this matrix.
    let feats = pipe.train_features_dense()?;
    let block = &feats[0];
    let mut t = Table::new(
        "zero-bin occupancy on real gradient features",
        &["bits", "absmax", "absmean"],
    );
    for bits in [8u8, 4, 2] {
        let mut hmax = BinHistogram::new(bits, Scheme::Absmax);
        let mut hmean = BinHistogram::new(bits, Scheme::Absmean);
        for i in 0..block.n {
            hmax.add_row(block.row(i));
            hmean.add_row(block.row(i));
        }
        t.row(vec![
            bits.to_string(),
            format!("{:.1}%", hmax.zero_bin_frac() * 100.0),
            format!("{:.1}%", hmean.zero_bin_frac() * 100.0),
        ]);
    }
    println!("{}", t.render());

    // (b) selection agreement vs the 16-bit reference (the metric that
    // matters: does coarse quantization pick the same data?). The whole
    // grid of datastores is built in ONE extraction pass (`--bits` sweep).
    let grid: Vec<Precision> = vec![
        Precision::new(16, Scheme::Absmax)?,
        Precision::new(8, Scheme::Absmax)?,
        Precision::new(4, Scheme::Absmax)?,
        Precision::new(4, Scheme::Absmean)?,
        Precision::new(2, Scheme::Absmax)?,
        Precision::new(2, Scheme::Absmean)?,
        Precision::new(1, Scheme::Sign)?,
    ];
    let stores = pipe.build_datastores(&grid)?;
    let (ds16, _) = &stores[0];
    let mut t2 = Table::new(
        "top-5% selection overlap with LESS 16-bit",
        &["precision", "SynQA", "SynMC", "SynArith"],
    );
    let mut ref_sel = std::collections::BTreeMap::new();
    for bench in Benchmark::ALL {
        let s = pipe.influence_scores(ds16, bench)?;
        ref_sel.insert(bench.name(), select_top_frac(&s, 0.05));
    }
    for (p, (ds, _)) in grid.iter().skip(1).zip(stores.iter().skip(1)) {
        let mut row = vec![p.label()];
        for bench in Benchmark::ALL {
            let s = pipe.influence_scores(ds, bench)?;
            let sel = select_top_frac(&s, 0.05);
            let r = &ref_sel[bench.name()];
            let overlap = sel.iter().filter(|i| r.contains(i)).count();
            row.push(format!("{:.0}%", 100.0 * overlap as f64 / r.len() as f64));
        }
        t2.row(row);
    }
    println!("{}", t2.render());
    println!("expectation (paper §5): overlap degrades gracefully with bits;\n2-bit absmax shifts most (zero-bin sparsity), absmean recovers it, 1-bit stays high.");
    Ok(())
}
