//! Selection-budget sweep (paper Fig. 4, selection-level view): how the
//! selected subset evolves as the budget grows from 0.1% to 10% with a
//! 1-bit gradient store — composition, score thresholds, and nesting.
//!
//! (The full fine-tune+eval version of Fig. 4 is `qless xp fig4`; this
//! example stays cheap by stopping at selection.)
//!
//! Run: `cargo run --release --example budget_sweep`

use anyhow::Result;
use qless::config::Config;
use qless::eval::Benchmark;
use qless::pipeline::Pipeline;
use qless::quant::{Precision, Scheme};
use qless::select::{select_top_frac, SourceDistribution};
use qless::util::table::Table;

fn main() -> Result<()> {
    let mut cfg = Config::default();
    cfg.model = "tiny".into();
    cfg.corpus_size = 2000;
    cfg.warmup_epochs = 2;
    cfg.val_per_task = 16;
    cfg.run_dir = "runs/budget_sweep".into();
    let mut pipe = Pipeline::new(cfg)?;

    let (ds, _) = pipe.build_datastore(Precision::new(1, Scheme::Sign)?)?;
    for bench in [Benchmark::SynArith, Benchmark::SynQA] {
        let scores = pipe.influence_scores(&ds, bench)?;
        let mut t = Table::new(
            &format!("{bench} — budget sweep (aligned source: {})", bench.aligned_source()),
            &["budget", "n", "min score", "aligned-source share", "composition"],
        );
        let mut prev: Option<Vec<usize>> = None;
        for frac in [0.001, 0.005, 0.01, 0.02, 0.05, 0.10] {
            let sel = select_top_frac(&scores, frac);
            let dist = SourceDistribution::of(&pipe.corpus.samples, &sel);
            // nesting check: smaller budgets are prefixes of larger ones
            if let Some(p) = &prev {
                assert!(p.iter().all(|i| sel.contains(i)), "selection not nested!");
            }
            let min_score = sel.iter().map(|&i| scores[i]).fold(f32::MAX, f32::min);
            t.row(vec![
                format!("{:.1}%", frac * 100.0),
                sel.len().to_string(),
                format!("{min_score:+.4}"),
                format!("{:.0}%", dist.frac(bench.aligned_source()) * 100.0),
                dist.render(),
            ]);
            prev = Some(sel);
        }
        println!("{}", t.render());
    }
    println!("expectation: tight budgets are dominated by the benchmark-aligned source;\nbroader budgets dilute toward the corpus mix (37/37/6/20%).");
    Ok(())
}
