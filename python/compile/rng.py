"""Deterministic cross-language RNG primitives.

The Rademacher random-projection matrix R used by QLESS must be *identical*
between the Python build/test path and the Rust runtime (Rust generates R and
feeds it to the AOT-compiled ``grad_train``/``grad_val`` graphs as an input
buffer, so it is never baked into the HLO). Both sides implement the same
counter-based splitmix64 stream:

    out_i = mix64(seed + (i + 1) * GOLDEN)

which is exactly the classic splitmix64 generator unrolled — element ``i`` of
the stream depends only on ``(seed, i)``, so it vectorizes in numpy and
parallelizes in Rust. ``rust/src/util/rng.rs`` mirrors this file; the parity
vectors in ``tests/test_rng.py`` and ``util::rng`` unit tests pin both.
"""

from __future__ import annotations

import numpy as np

GOLDEN = np.uint64(0x9E3779B97F4A7C15)
_MIX1 = np.uint64(0xBF58476D1CE4E5B9)
_MIX2 = np.uint64(0x94D049BB133111EB)


def splitmix64(seed: int, n: int, offset: int = 0) -> np.ndarray:
    """Elements ``offset .. offset+n`` of the splitmix64 stream for ``seed``.

    Returns an ``np.uint64`` array of length ``n``.
    """
    idx = np.arange(offset + 1, offset + n + 1, dtype=np.uint64)
    with np.errstate(over="ignore"):
        z = np.uint64(seed) + idx * GOLDEN
        z = (z ^ (z >> np.uint64(30))) * _MIX1
        z = (z ^ (z >> np.uint64(27))) * _MIX2
        z = z ^ (z >> np.uint64(31))
    return z


def rademacher_projection(seed: int, d: int, k: int) -> np.ndarray:
    """The QLESS projection matrix R ∈ {−1,+1}^{d×k} / sqrt(k), row-major.

    Sign of element (i, j) is bit 63 of stream element ``i*k + j``.
    By Johnson–Lindenstrauss (Achlioptas 2003, database-friendly variant),
    x ↦ xᵀR approximately preserves inner products for k ≪ d.
    """
    bits = splitmix64(seed, d * k) >> np.uint64(63)
    signs = np.where(bits == 1, -1.0, 1.0).astype(np.float32)
    return (signs / np.float32(np.sqrt(k))).reshape(d, k)


def uniform01(seed: int, n: int, offset: int = 0) -> np.ndarray:
    """float64 uniforms in [0,1) from the top 53 bits of the stream."""
    z = splitmix64(seed, n, offset)
    return (z >> np.uint64(11)).astype(np.float64) / float(1 << 53)
