"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: pytest sweeps shapes/bit-widths with
hypothesis and asserts the Pallas kernels (interpret mode) match these
references exactly (quantization is integer-valued, so the comparison is
exact; the influence matmul is compared with tight fp32 tolerances).

They are also the *semantic specification* that the Rust-native quantizer and
scorer (``rust/src/quant``, ``rust/src/influence/native.rs``) implement —
the integration tests compare Rust output against features produced here.
"""

from __future__ import annotations

import jax.numpy as jnp

from ..simconfig import ABSMEAN_C


def alpha_for_bits(bits: int) -> float:
    """α = 2^(b−1) − 1, the outermost quantization level (paper Eq. 5)."""
    if bits < 2 or bits > 8:
        raise ValueError(f"alpha_for_bits: bits must be in [2,8], got {bits}")
    return float(2 ** (bits - 1) - 1)


def quantize_absmax_ref(g: jnp.ndarray, alpha: float):
    """Paper Eq. 4–5: per-row absmax scaling, symmetric uniform quantization.

    g: [n, k] float32.  Returns (codes int8 in [−α, α], scales [n] float32)
    where ``scales`` is S/α so that dequantized values are codes*scales.
    """
    s = jnp.max(jnp.abs(g), axis=-1, keepdims=True)
    safe = jnp.where(s > 0, s, 1.0)
    q = jnp.clip(jnp.round(alpha * g / safe), -alpha, alpha).astype(jnp.int8)
    return q, (jnp.where(s > 0, s, 0.0) / alpha)[:, 0]


def quantize_absmean_ref(g: jnp.ndarray, alpha: float):
    """Absmean variant (paper §5): scale by c·mean|g| instead of max|g|.

    Values beyond c·mean|g| saturate to ±α, pushing mass out of the zero
    bin — denser codes at 2/4-bit (Fig. 3), clipped tails at 8-bit.
    """
    s = ABSMEAN_C * jnp.mean(jnp.abs(g), axis=-1, keepdims=True)
    safe = jnp.where(s > 0, s, 1.0)
    q = jnp.clip(jnp.round(alpha * g / safe), -alpha, alpha).astype(jnp.int8)
    return q, (jnp.where(s > 0, s, 0.0) / alpha)[:, 0]


def quantize_sign_ref(g: jnp.ndarray):
    """1-bit sign quantization (paper Table 3 "Sign"): q ∈ {−1, +1}.

    No zero bin by construction; scale is mean|g| (the optimal per-row
    reconstruction scale for sign codes, as in signSGD / BitNet).
    """
    q = jnp.where(g >= 0, 1, -1).astype(jnp.int8)
    return q, jnp.mean(jnp.abs(g), axis=-1)


def quantize(g: jnp.ndarray, scheme: str, bits: int):
    """Dispatch helper mirroring rust/src/quant/scheme.rs."""
    if bits == 16:
        return g, None  # LESS baseline: no quantization
    if bits == 1:
        return quantize_sign_ref(g)
    if scheme == "absmax":
        return quantize_absmax_ref(g, alpha_for_bits(bits))
    if scheme == "absmean":
        return quantize_absmean_ref(g, alpha_for_bits(bits))
    raise ValueError(f"unknown scheme {scheme}")


def normalize_rows_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Row-normalize (paper Eq. 2 / Eq. 6). Zero rows stay zero."""
    n = jnp.linalg.norm(x.astype(jnp.float32), axis=-1, keepdims=True)
    return x / jnp.where(n > 0, n, 1.0)


def influence_ref(qt: jnp.ndarray, qv: jnp.ndarray) -> jnp.ndarray:
    """Cosine-similarity tile (paper Eq. 7 inner term).

    qt: [nt, k] train codes (any real dtype), qv: [nv, k] val codes.
    Returns [nt, nv] of ⟨q̂_z, q̂_z'⟩.  The per-row quantization scale
    cancels under normalization — the scorer never needs it.
    """
    return normalize_rows_ref(qt) @ normalize_rows_ref(qv).T


def dequantize_ref(codes: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    """codes [n,k] int8 × scales [n] → float32 reconstruction."""
    return codes.astype(jnp.float32) * scales[:, None]
