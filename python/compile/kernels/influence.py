"""L1 Pallas kernel: tiled cosine-similarity matmul (paper Eq. 7).

The influence hot-spot is ⟨q̂_z, q̂_z'⟩ over every (train, val) pair: an
(N_train × k) · (k × N_val) matmul where both operands are row-normalized
quantized gradients. For the paper's full scale (270K × 8192) this is the
dominant scoring cost, so it is the MXU target:

  * grid tiles the output (bq × bv); each step loads a (bq × k) train tile
    and a (bv × k) val tile into VMEM — at bq=128, bv=64, k=8192 that is
    4 MB + 2 MB fp32, inside the ~16 MB VMEM budget with double-buffering;
  * the inner contraction is a k-deep matmul feeding the 128×128 systolic
    array (``preferred_element_type=float32`` keeps fp32 accumulation even
    for bf16/int8-cast inputs);
  * row norms are computed in-tile (VPU) and fused ahead of the matmul, so
    normalized operands never round-trip to HBM.

GPU→TPU adaptation: the paper's implementation normalizes gradients in
global memory and calls cuBLAS; here normalization lives in the same kernel
as the matmul tile, trading a small redundant norm recompute (once per
opposing tile) for zero extra HBM traffic — the classic VMEM-locality trade.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _influence_kernel(qt_ref, qv_ref, out_ref):
    qt = qt_ref[...].astype(jnp.float32)
    qv = qv_ref[...].astype(jnp.float32)
    tn = jnp.sqrt(jnp.sum(qt * qt, axis=-1, keepdims=True))
    vn = jnp.sqrt(jnp.sum(qv * qv, axis=-1, keepdims=True))
    qt = qt / jnp.where(tn > 0, tn, 1.0)
    qv = qv / jnp.where(vn > 0, vn, 1.0)
    out_ref[...] = jax.lax.dot_general(
        qt, qv,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("bq", "bv"))
def influence_pallas(qt: jnp.ndarray, qv: jnp.ndarray, bq: int = 128, bv: int = 64):
    """Cosine-similarity matrix [nt, nv] between row sets qt [nt,k], qv [nv,k].

    nt % bq == 0 and nv % bv == 0 (runtime pads tail tiles with zero rows,
    which produce zero similarity and are sliced off afterwards).
    """
    nt, k = qt.shape
    nv, k2 = qv.shape
    assert k == k2, (k, k2)
    assert nt % bq == 0 and nv % bv == 0, (nt, bq, nv, bv)
    return pl.pallas_call(
        _influence_kernel,
        grid=(nt // bq, nv // bv),
        in_specs=[
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bv, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bv), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nt, nv), jnp.float32),
        interpret=True,
    )(qt, qv)
