"""L1 Pallas kernels: absmax / absmean / sign gradient quantization.

QLESS step 3 (paper §3.1): given a block of randomly-projected gradient
features g ∈ R^{n×k}, emit b-bit integer codes plus one fp32 scale per row.

Kernel structure (the TPU story — see DESIGN.md §Hardware-Adaptation):
  * grid over row blocks; each grid step owns ``block`` rows × full k in VMEM
    (k ≤ 8192 fp32 rows are ~32 KB each — far under the ~16 MB VMEM budget,
    so the row reduction max|g| / mean|g| never touches HBO twice);
  * the reduction and the round/clip are VPU element-wise work, deliberately
    fused into one kernel so only the int8 codes cross back to HBM;
  * bit-*packing* below 8 bits is not done here: XLA has no sub-byte dtypes,
    so the runtime packs int8 codes into 1/2/4-bit words on the Rust side
    (``rust/src/quant/pack.rs``) right before they hit the datastore.

Runs under ``interpret=True`` (CPU PJRT cannot execute Mosaic custom-calls);
numerics are validated against ``ref.py`` by pytest/hypothesis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..simconfig import ABSMEAN_C


def _quant_kernel(g_ref, codes_ref, scales_ref, *, alpha: float, mode: str):
    """One grid step: quantize ``block`` rows resident in VMEM."""
    g = g_ref[...]
    if mode == "absmax":
        s = jnp.max(jnp.abs(g), axis=-1, keepdims=True)
    elif mode == "absmean":
        s = ABSMEAN_C * jnp.mean(jnp.abs(g), axis=-1, keepdims=True)
    else:
        raise ValueError(mode)
    safe = jnp.where(s > 0, s, 1.0)
    q = jnp.clip(jnp.round(alpha * g / safe), -alpha, alpha)
    codes_ref[...] = q.astype(jnp.int8)
    # Store S/α: dequantization is then codes * scale.
    scales_ref[...] = (jnp.where(s > 0, s, 0.0) / alpha)[:, 0]


def _sign_kernel(g_ref, codes_ref, scales_ref):
    """1-bit sign quantization — no zero bin (paper §5, Fig. 3)."""
    g = g_ref[...]
    codes_ref[...] = jnp.where(g >= 0, 1, -1).astype(jnp.int8)
    scales_ref[...] = jnp.mean(jnp.abs(g), axis=-1)


@functools.partial(jax.jit, static_argnames=("bits", "mode", "block"))
def quantize_pallas(g: jnp.ndarray, bits: int, mode: str = "absmax", block: int = 64):
    """Quantize g [n, k] → (codes int8 [n, k], scales f32 [n]).

    n must be a multiple of ``block`` (the runtime pads the tail batch).
    ``bits == 1`` selects the sign kernel regardless of ``mode``.
    """
    n, k = g.shape
    assert n % block == 0, (n, block)
    grid = (n // block,)
    row_spec = pl.BlockSpec((block, k), lambda i: (i, 0))
    scale_spec = pl.BlockSpec((block,), lambda i: (i,))
    out_shape = (
        jax.ShapeDtypeStruct((n, k), jnp.int8),
        jax.ShapeDtypeStruct((n,), jnp.float32),
    )
    if bits == 1:
        kernel = _sign_kernel
    else:
        alpha = float(2 ** (bits - 1) - 1)
        kernel = functools.partial(_quant_kernel, alpha=alpha, mode=mode)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[row_spec],
        out_specs=(row_spec, scale_spec),
        out_shape=out_shape,
        interpret=True,
    )(g)
