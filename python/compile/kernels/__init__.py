# L1: Pallas kernels for the QLESS compute hot-spots.
from .quantize import quantize_pallas  # noqa: F401
from .influence import influence_pallas  # noqa: F401
