"""L2: SimLM — the JAX causal transformer whose LoRA gradients QLESS values.

The paper runs LESS/QLESS on 3–8B decoder LMs; the reproduction substitutes
SimLM, a genuine (if small) causal transformer — multi-head attention,
GELU MLP, RMSNorm, weight-tied embeddings — with LoRA adapters on the
q/k/v/o projections, exactly the adapter placement of the paper
(Appendix A: "learned LoRA matrices for query, key, value, and output").

Everything is expressed over **flat fp32 parameter vectors** (``base_flat``
frozen, ``lora_flat`` trainable) so each exported HLO graph has a small,
stable signature and the Rust runtime can hold parameters as plain
``Vec<f32>`` device buffers uploaded once per checkpoint.

Graphs exported by ``aot.py`` (see DESIGN.md §3):
  train_step       Adam update of LoRA params on a batch (warmup + finetune)
  grad_train       per-sample Adam-preconditioned LoRA grads → R-projection
  grad_val         per-sample SGD grads → R-projection
  loss_eval        per-sample masked NLL (MC ranking / perplexity)
  decode_step      next-token logits at a given position (greedy decode)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .simconfig import ADAM_B1, ADAM_B2, ADAM_EPS, ModelConfig

# ---------------------------------------------------------------------------
# flat <-> structured parameters
# ---------------------------------------------------------------------------


def _numel(shape):
    n = 1
    for s in shape:
        n *= s
    return n


def unflatten(flat: jnp.ndarray, shapes) -> dict:
    """Split a flat vector into named arrays following a shape list."""
    out, off = {}, 0
    for name, shape in shapes:
        n = _numel(shape)
        out[name] = flat[off:off + n].reshape(shape)
        off += n
    assert off == flat.shape[0], (off, flat.shape)
    return out


def init_base_flat(cfg: ModelConfig, key) -> jnp.ndarray:
    """Initialize frozen base parameters (scaled-normal / ones for norms)."""
    parts = []
    for name, shape in cfg.base_shapes():
        key, sub = jax.random.split(key)
        if len(shape) == 1:  # RMSNorm gains
            parts.append(jnp.ones(shape, jnp.float32))
        elif name == "embed":
            parts.append(0.05 * jax.random.normal(sub, shape, jnp.float32))
        else:
            fan_in = shape[0]
            parts.append(jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(fan_in))
    return jnp.concatenate([p.reshape(-1) for p in parts])


def init_lora_flat(cfg: ModelConfig, key) -> jnp.ndarray:
    """LoRA init: A ~ N(0, 1/r), B = 0 (standard — adapters start as no-op)."""
    parts = []
    for name, shape in cfg.lora_shapes():
        key, sub = jax.random.split(key)
        if name.endswith(".A"):
            parts.append(jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(cfg.lora_rank))
        else:
            parts.append(jnp.zeros(shape, jnp.float32))
    return jnp.concatenate([p.reshape(-1) for p in parts])


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def rmsnorm(x, gain):
    return x * gain / jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def forward(cfg: ModelConfig, base_flat, lora_flat, tokens):
    """Causal LM forward for one unbatched sequence.

    tokens: [S] int32. Returns logits [S, V].
    Batch dims are added by ``jax.vmap`` at export time — this keeps the
    per-sample-gradient graph (vmap of grad of this) straightforward.
    """
    b = unflatten(base_flat, cfg.base_shapes())
    lo = unflatten(lora_flat, cfg.lora_shapes())
    D, H, S = cfg.d_model, cfg.n_heads, cfg.seq
    hd = D // H
    scale = cfg.lora_alpha / cfg.lora_rank

    x = b["embed"][tokens]  # [S, D]
    causal = jnp.triu(jnp.full((S, S), -1e9, jnp.float32), 1)

    def lora_proj(h, w, A, B):
        return h @ w + scale * ((h @ A) @ B)

    for l in range(cfg.n_layers):
        h = rmsnorm(x, b[f"l{l}.ln1"])
        q = lora_proj(h, b[f"l{l}.wq"], lo[f"l{l}.q.A"], lo[f"l{l}.q.B"])
        k = lora_proj(h, b[f"l{l}.wk"], lo[f"l{l}.k.A"], lo[f"l{l}.k.B"])
        v = lora_proj(h, b[f"l{l}.wv"], lo[f"l{l}.v.A"], lo[f"l{l}.v.B"])
        # [S, D] -> [H, S, hd]
        q = q.reshape(S, H, hd).transpose(1, 0, 2)
        k = k.reshape(S, H, hd).transpose(1, 0, 2)
        v = v.reshape(S, H, hd).transpose(1, 0, 2)
        att = jax.nn.softmax(
            jnp.einsum("hqd,hkd->hqk", q, k) / jnp.sqrt(hd) + causal, axis=-1
        )
        o = jnp.einsum("hqk,hkd->hqd", att, v).transpose(1, 0, 2).reshape(S, D)
        o = lora_proj(o, b[f"l{l}.wo"], lo[f"l{l}.o.A"], lo[f"l{l}.o.B"])
        x = x + o
        h = rmsnorm(x, b[f"l{l}.ln2"])
        x = x + jax.nn.gelu(h @ b[f"l{l}.w1"]) @ b[f"l{l}.w2"]

    x = rmsnorm(x, b["lnf"])
    return x @ b["embed"].T  # weight-tied head


def sample_loss(cfg: ModelConfig, lora_flat, base_flat, tokens, lmask):
    """Masked next-token NLL for one sequence, averaged over target tokens.

    lmask[t] = 1 marks token t as part of the answer span (instruction-tuning
    loss masking). The per-sample *mean* over tokens is deliberate: it is the
    token-averaged gradient whose length bias LESS's normalization (Eq. 2)
    corrects, so the reproduction keeps it.
    """
    logits = forward(cfg, base_flat, lora_flat, tokens)
    lp = jax.nn.log_softmax(logits[:-1], axis=-1)
    tgt = tokens[1:]
    ll = jnp.take_along_axis(lp, tgt[:, None], axis=-1)[:, 0]
    w = lmask[1:]
    return -(ll * w).sum() / jnp.maximum(w.sum(), 1.0)


# ---------------------------------------------------------------------------
# exported graphs
# ---------------------------------------------------------------------------


def batch_loss(cfg, lora_flat, base_flat, tokens, lmask):
    per = jax.vmap(sample_loss, in_axes=(None, None, None, 0, 0))(
        cfg, lora_flat, base_flat, tokens, lmask
    )
    return per.mean()


def train_step(cfg: ModelConfig, base_flat, lora_flat, m, v, t, tokens, lmask, lr):
    """One Adam step on the LoRA params (paper Appendix A hyperparams).

    t is the 1-based step count *as float* (HLO-friendly); returns
    (lora', m', v', loss).
    """
    loss, g = jax.value_and_grad(batch_loss, argnums=1)(
        cfg, lora_flat, base_flat, tokens, lmask
    )
    m2 = ADAM_B1 * m + (1.0 - ADAM_B1) * g
    v2 = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
    mhat = m2 / (1.0 - ADAM_B1 ** t)
    vhat = v2 / (1.0 - ADAM_B2 ** t)
    lora2 = lora_flat - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    return lora2, m2, v2, loss


def pretrain_step(cfg: ModelConfig, base_flat, m, v, t, tokens, lmask, lr):
    """One Adam step on the **base** parameters (LoRA disabled).

    The paper fine-tunes pretrained LLMs; the reproduction creates its
    "pretrained base" by running this step over a generic corpus before any
    warmup/selection happens (DESIGN.md §2). Returns (base', m', v', loss).
    """

    def loss_fn(bf):
        per = jax.vmap(sample_loss, in_axes=(None, None, None, 0, 0))(
            cfg, jnp.zeros((cfg.d_lora,), jnp.float32), bf, tokens, lmask
        )
        return per.mean()

    loss, g = jax.value_and_grad(loss_fn)(base_flat)
    m2 = ADAM_B1 * m + (1.0 - ADAM_B1) * g
    v2 = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
    mhat = m2 / (1.0 - ADAM_B1 ** t)
    vhat = v2 / (1.0 - ADAM_B2 ** t)
    base2 = base_flat - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    return base2, m2, v2, loss


def grad_train_features(cfg: ModelConfig, base_flat, lora_flat, m, v, t, tokens, lmask, proj):
    """Per-sample **Adam** gradient features Γ(z;θ) projected by R (LESS §2.2).

    Γ is the Adam update direction the sample *would* induce given the
    checkpoint's optimizer state (m, v): the LESS/TracIn-style training
    gradient. vmap(grad) gives exact per-sample grads in one fused graph.
    Returns feats [B, K] — unnormalized; quantization + normalization happen
    downstream (QLESS Eq. 5–6).
    """
    g = jax.vmap(jax.grad(sample_loss, argnums=1), in_axes=(None, None, None, 0, 0))(
        cfg, lora_flat, base_flat, tokens, lmask
    )  # [B, d_lora]
    mhat = (ADAM_B1 * m[None, :] + (1.0 - ADAM_B1) * g) / (1.0 - ADAM_B1 ** t)
    vhat = (ADAM_B2 * v[None, :] + (1.0 - ADAM_B2) * g * g) / (1.0 - ADAM_B2 ** t)
    gamma = mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    return gamma @ proj


def grad_val_features(cfg: ModelConfig, base_flat, lora_flat, tokens, lmask, proj):
    """Per-sample **SGD** gradient features ∇ℓ(z';θ) projected by R."""
    g = jax.vmap(jax.grad(sample_loss, argnums=1), in_axes=(None, None, None, 0, 0))(
        cfg, lora_flat, base_flat, tokens, lmask
    )
    return g @ proj


def loss_eval(cfg: ModelConfig, base_flat, lora_flat, tokens, lmask):
    """Per-sample masked NLL [B] — option ranking (SynMC) and perplexity."""
    return jax.vmap(sample_loss, in_axes=(None, None, None, 0, 0))(
        cfg, lora_flat, base_flat, tokens, lmask
    )


def decode_step(cfg: ModelConfig, base_flat, lora_flat, tokens, pos):
    """Logits at position ``pos`` per sequence: (tokens [B,S], pos [B]) → [B,V].

    The Rust greedy decoder appends argmax(logits) at pos+1 and re-invokes;
    the full-sequence forward is recomputed each step (no KV cache — S is 96
    and the eval batch is small; see DESIGN.md §7 for the trade-off note).
    """
    logits = jax.vmap(forward, in_axes=(None, None, None, 0))(
        cfg, base_flat, lora_flat, tokens
    )  # [B, S, V]
    return jnp.take_along_axis(logits, pos[:, None, None], axis=1)[:, 0, :]
