"""Shared model / artifact-shape configuration for the AOT pipeline.

Single source of truth for every static dimension that the Rust runtime has
to agree on. ``aot.py`` serializes the chosen configs into
``artifacts/manifest.json``; ``rust/src/runtime/manifest.rs`` parses and
validates it at load time so a stale artifact directory fails fast instead
of producing shape errors deep inside PJRT.
"""

from __future__ import annotations

from dataclasses import dataclass, asdict, field

# Character-level vocabulary shared with rust/src/corpus/tokenizer.rs.
# Index 0 is <pad>; 1 <bos>; 2 <eot> (end of turn); 3 <sep>.
VOCAB = ["<pad>", "<bos>", "<eot>", "<sep>"] + list(
    "abcdefghijklmnopqrstuvwxyz0123456789 .,:;?!'\"()+-*/=%<>|&#@_"
)
VOCAB_SIZE = 64
assert len(VOCAB) == VOCAB_SIZE, len(VOCAB)

# Adam hyperparameters (paper Appendix A uses AdamW defaults on LoRA params).
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8

# absmean saturation constant: values beyond ABSMEAN_C * mean|g| clip to the
# outer bin.  For a Gaussian, mean|g| ≈ 0.8σ, so c=2.5 saturates ≈2σ — this
# pushes mass away from the zero bin (paper §5, Fig. 3) at the cost of
# clipping the tail.
ABSMEAN_C = 2.5


@dataclass(frozen=True)
class ModelConfig:
    """A SimLM size preset plus the static batch shapes of its artifacts."""

    name: str
    vocab: int = VOCAB_SIZE
    seq: int = 96        # S: fixed sequence length (char-level)
    d_model: int = 128   # D
    n_layers: int = 4    # L
    n_heads: int = 4     # H
    d_ff: int = 512      # F
    lora_rank: int = 8   # r (LoRA on q,k,v,o)
    lora_alpha: float = 16.0
    proj_dim: int = 512  # K: random-projection dim (paper uses 8192 at 270K)
    batch_train: int = 16  # B for train_step
    batch_grad: int = 16   # B for grad_train / grad_val (vmapped per-sample)
    batch_eval: int = 32   # B for loss_eval / decode_step
    tile_q: int = 128      # influence kernel: train-side tile rows
    tile_v: int = 64       # influence kernel: val-side tile rows
    quant_block: int = 64  # quantize kernel: rows per grid step

    # ---- derived shapes ----------------------------------------------------

    def base_shapes(self):
        """Flat-packing order of frozen base params (must match model.py)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        shapes = [("embed", (v, d))]
        for l in range(self.n_layers):
            shapes += [
                (f"l{l}.wq", (d, d)),
                (f"l{l}.wk", (d, d)),
                (f"l{l}.wv", (d, d)),
                (f"l{l}.wo", (d, d)),
                (f"l{l}.ln1", (d,)),
                (f"l{l}.w1", (d, f)),
                (f"l{l}.w2", (f, d)),
                (f"l{l}.ln2", (d,)),
            ]
        shapes.append(("lnf", (d,)))
        return shapes

    def lora_shapes(self):
        """Flat-packing order of trainable LoRA params (q,k,v,o per layer)."""
        d, r = self.d_model, self.lora_rank
        shapes = []
        for l in range(self.n_layers):
            for w in ("q", "k", "v", "o"):
                shapes += [(f"l{l}.{w}.A", (d, r)), (f"l{l}.{w}.B", (r, d))]
        return shapes

    @property
    def d_base(self) -> int:
        return sum(_numel(s) for _, s in self.base_shapes())

    @property
    def d_lora(self) -> int:
        return sum(_numel(s) for _, s in self.lora_shapes())

    def manifest_entry(self) -> dict:
        d = asdict(self)
        d["d_base"] = self.d_base
        d["d_lora"] = self.d_lora
        d["adam_b1"] = ADAM_B1
        d["adam_b2"] = ADAM_B2
        d["adam_eps"] = ADAM_EPS
        d["absmean_c"] = ABSMEAN_C
        return d


def _numel(shape) -> int:
    n = 1
    for s in shape:
        n *= s
    return n


TINY = ModelConfig(
    name="tiny", d_model=64, n_layers=2, n_heads=2, d_ff=256,
    lora_rank=4, proj_dim=256, tile_q=64, tile_v=32,
)
SMALL = ModelConfig(name="small")  # defaults above
BASE = ModelConfig(
    name="base", d_model=256, n_layers=6, n_heads=8, d_ff=1024,
    lora_rank=8, proj_dim=512,
)

CONFIGS = {c.name: c for c in (TINY, SMALL, BASE)}
