"""AOT export: lower every L2/L1 graph to HLO **text** + write the manifest.

Interchange is HLO text, NOT ``HloModule.serialize()``: jax ≥ 0.5 emits
protos with 64-bit instruction ids which the runtime's xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Run once via ``make artifacts``; Python never executes on the request path.

Usage:  cd python && python -m compile.aot --out ../artifacts [--sizes tiny,small,base]
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import influence_pallas, quantize_pallas
from .simconfig import CONFIGS, VOCAB, ModelConfig

MANIFEST_VERSION = 2


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def graphs_for(cfg: ModelConfig):
    """(name, jitted fn, example arg specs) for every artifact of one size."""
    db, dl, K = cfg.d_base, cfg.d_lora, cfg.proj_dim
    S, Bt, Bg, Be = cfg.seq, cfg.batch_train, cfg.batch_grad, cfg.batch_eval
    f32, i32 = jnp.float32, jnp.int32

    def j(fn):
        return jax.jit(functools.partial(fn, cfg))

    out = [
        (
            "pretrain_step",
            j(model.pretrain_step),
            [_spec((db,)), _spec((db,)), _spec((db,)), _spec((), f32),
             _spec((Bt, S), i32), _spec((Bt, S), f32), _spec((), f32)],
        ),
        (
            "train_step",
            j(model.train_step),
            [_spec((db,)), _spec((dl,)), _spec((dl,)), _spec((dl,)), _spec((), f32),
             _spec((Bt, S), i32), _spec((Bt, S), f32), _spec((), f32)],
        ),
        (
            "grad_train",
            j(model.grad_train_features),
            [_spec((db,)), _spec((dl,)), _spec((dl,)), _spec((dl,)), _spec((), f32),
             _spec((Bg, S), i32), _spec((Bg, S), f32), _spec((dl, K))],
        ),
        (
            "grad_val",
            j(model.grad_val_features),
            [_spec((db,)), _spec((dl,)), _spec((Bg, S), i32), _spec((Bg, S), f32),
             _spec((dl, K))],
        ),
        (
            "loss_eval",
            j(model.loss_eval),
            [_spec((db,)), _spec((dl,)), _spec((Be, S), i32), _spec((Be, S), f32)],
        ),
        (
            "decode_step",
            j(model.decode_step),
            [_spec((db,)), _spec((dl,)), _spec((Be, S), i32), _spec((Be,), i32)],
        ),
    ]

    # L1 Pallas kernels, exported at the tile shapes the runtime chunks to.
    # Quantize tiles: (quant_block × K); influence tiles: (tile_q × K)·(K × tile_v).
    qb = cfg.quant_block
    for scheme, bits_list in (("absmax", (8, 4, 2)), ("absmean", (8, 4, 2)), ("sign", (1,))):
        for bits in bits_list:
            name = f"quantize_{scheme}_{bits}" if bits != 1 else "quantize_sign_1"
            mode = "absmax" if scheme == "sign" else scheme
            fn = jax.jit(
                functools.partial(quantize_pallas, bits=bits, mode=mode, block=qb)
            )
            out.append((name, fn, [_spec((qb, K))]))

    out.append(
        (
            "influence",
            jax.jit(functools.partial(influence_pallas, bq=cfg.tile_q, bv=cfg.tile_v)),
            [_spec((cfg.tile_q, K)), _spec((cfg.tile_v, K))],
        )
    )
    return out


def export_size(cfg: ModelConfig, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    artifacts = {}
    for name, fn, specs in graphs_for(cfg):
        t0 = time.time()
        text = to_hlo_text(fn.lower(*specs))
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        artifacts[name] = {
            "file": f"{cfg.name}/{name}.hlo.txt",
            "inputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
            ],
        }
        print(f"  {cfg.name}/{name}: {len(text)//1024} KiB in {time.time()-t0:.1f}s")
    return artifacts


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--sizes", default="tiny,small,base")
    args = ap.parse_args()

    manifest = {"version": MANIFEST_VERSION, "vocab": VOCAB, "models": {}}
    for size in args.sizes.split(","):
        cfg = CONFIGS[size]
        print(f"[aot] exporting {size} (d_base={cfg.d_base} d_lora={cfg.d_lora})")
        entry = cfg.manifest_entry()
        entry["artifacts"] = export_size(cfg, os.path.join(args.out, size))
        manifest["models"][size] = entry

    path = os.path.join(args.out, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] wrote {path}")


if __name__ == "__main__":
    main()
