"""AOT export sanity: every graph lowers to parseable HLO text with the
shapes the manifest promises. Uses the tiny config to keep lowering fast."""

import json
import os

import pytest

from compile.aot import graphs_for, to_hlo_text
from compile.simconfig import CONFIGS, TINY, VOCAB


@pytest.fixture(scope="module")
def lowered():
    out = {}
    for name, fn, specs in graphs_for(TINY):
        out[name] = (fn.lower(*specs), specs)
    return out


EXPECTED = {
    "pretrain_step", "train_step", "grad_train", "grad_val", "loss_eval", "decode_step",
    "quantize_absmax_8", "quantize_absmax_4", "quantize_absmax_2",
    "quantize_absmean_8", "quantize_absmean_4", "quantize_absmean_2",
    "quantize_sign_1", "influence",
}


def test_graph_set_complete(lowered):
    assert set(lowered) == EXPECTED


def test_hlo_text_is_parseable_entry(lowered):
    for name, (low, _) in lowered.items():
        text = to_hlo_text(low)
        assert "ENTRY" in text, name
        assert "HloModule" in text, name
        # 64-bit-id regression guard: text path always starts ids small.
        assert len(text) > 200, name


def test_train_step_signature(lowered):
    _, specs = lowered["train_step"]
    shapes = [tuple(s.shape) for s in specs]
    assert shapes == [
        (TINY.d_base,), (TINY.d_lora,), (TINY.d_lora,), (TINY.d_lora,), (),
        (TINY.batch_train, TINY.seq), (TINY.batch_train, TINY.seq), (),
    ]


def test_grad_train_projection_shape(lowered):
    _, specs = lowered["grad_train"]
    assert tuple(specs[-1].shape) == (TINY.d_lora, TINY.proj_dim)


def test_influence_tile_shape(lowered):
    _, specs = lowered["influence"]
    assert tuple(specs[0].shape) == (TINY.tile_q, TINY.proj_dim)
    assert tuple(specs[1].shape) == (TINY.tile_v, TINY.proj_dim)


def test_manifest_entries_have_dims():
    for name, cfg in CONFIGS.items():
        e = cfg.manifest_entry()
        for k in ("d_base", "d_lora", "proj_dim", "seq", "vocab", "adam_b1",
                  "absmean_c"):
            assert k in e, (name, k)
        assert e["vocab"] == len(VOCAB) == 64


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built",
)
def test_built_manifest_matches_configs():
    path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
    with open(path) as f:
        man = json.load(f)
    assert man["version"] >= 2
    for size, entry in man["models"].items():
        cfg = CONFIGS[size]
        assert entry["d_base"] == cfg.d_base
        assert entry["d_lora"] == cfg.d_lora
        for art in entry["artifacts"].values():
            f_path = os.path.join(os.path.dirname(path), art["file"])
            assert os.path.exists(f_path), art["file"]
