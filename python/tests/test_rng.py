"""Parity tests for the cross-language splitmix64 / Rademacher stream.

The vectors pinned here are also pinned on the Rust side
(rust/src/util/rng.rs tests) — if either side drifts, projection matrices
diverge and every stored gradient feature silently stops matching.
"""

import numpy as np
import pytest

from compile.rng import GOLDEN, rademacher_projection, splitmix64, uniform01


def _scalar_splitmix64(seed: int, i: int) -> int:
    """Textbook splitmix64, call i (1-based), as an independent oracle."""
    mask = (1 << 64) - 1
    z = (seed + i * 0x9E3779B97F4A7C15) & mask
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & mask
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & mask
    return z ^ (z >> 31)


def test_matches_scalar_oracle():
    out = splitmix64(42, 16)
    for i in range(16):
        assert int(out[i]) == _scalar_splitmix64(42, i + 1)


def test_offset_slices_stream():
    full = splitmix64(7, 100)
    tail = splitmix64(7, 60, offset=40)
    assert np.array_equal(full[40:], tail)


def test_seed_zero_and_large_seed():
    assert int(splitmix64(0, 1)[0]) == _scalar_splitmix64(0, 1)
    big = (1 << 64) - 3
    assert int(splitmix64(big, 1)[0]) == _scalar_splitmix64(big, 1)


# Pinned vectors (duplicated in rust/src/util/rng.rs::tests::parity_vectors).
PINNED = {
    (1234, 0): 0xBB0CF61B2F181CDB,
    (1234, 1): 0x97C7A1364DF06524,
    (1234, 7): 0x3A465F3F8F9CE09F,
}


def test_pinned_vectors():
    out = splitmix64(1234, 8)
    for (seed, i), want in PINNED.items():
        assert int(out[i]) == want, f"stream({seed})[{i}]"


def test_pinned_vectors_are_right():
    # Guard the guard: pinned values must come from the scalar oracle.
    for (seed, i), want in PINNED.items():
        assert _scalar_splitmix64(seed, i + 1) == want


def test_projection_shape_and_values():
    r = rademacher_projection(99, 64, 32)
    assert r.shape == (64, 32)
    assert r.dtype == np.float32
    u = np.unique(np.abs(r))
    assert len(u) == 1
    np.testing.assert_allclose(u[0], 1.0 / np.sqrt(32), rtol=1e-6)


def test_projection_deterministic_and_seed_sensitive():
    a = rademacher_projection(5, 16, 8)
    b = rademacher_projection(5, 16, 8)
    c = rademacher_projection(6, 16, 8)
    assert np.array_equal(a, b)
    assert not np.array_equal(a, c)


def test_projection_sign_balance():
    r = rademacher_projection(1, 128, 128)
    frac_pos = (r > 0).mean()
    assert 0.45 < frac_pos < 0.55


def test_projection_preserves_inner_products():
    # JL sanity: relative inner products survive projection.
    rng = np.random.default_rng(0)
    x = rng.standard_normal((4, 2048)).astype(np.float32)
    r = rademacher_projection(3, 2048, 512)
    y = x @ r
    gx = x @ x.T
    gy = y @ y.T
    # JL additive bound: |⟨Rx,Ry⟩−⟨x,y⟩| ≲ c·‖x‖‖y‖/√k. Norms here are ~√2048,
    # so allow a few × 2048/√512 ≈ 90 of absolute slack on cross terms and
    # tight relative error on the (large) diagonal.
    np.testing.assert_allclose(np.diag(gy), np.diag(gx), rtol=0.15)
    np.testing.assert_allclose(gy, gx, atol=6 * 2048 / np.sqrt(512))


def test_uniform01_range():
    u = uniform01(11, 1000)
    assert u.min() >= 0.0 and u.max() < 1.0
    assert abs(u.mean() - 0.5) < 0.05


def test_golden_constant():
    assert int(GOLDEN) == 0x9E3779B97F4A7C15
