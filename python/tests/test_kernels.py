"""L1 correctness: Pallas kernels (interpret mode) vs pure-jnp oracles.

Hypothesis sweeps shapes / bit-widths / value distributions; quantization is
integer-valued so comparisons are exact, the influence matmul uses tight
fp32 tolerances. These are the CORE correctness signal for the kernels that
end up inside the AOT artifacts.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import influence_pallas, quantize_pallas
from compile.kernels.ref import (
    alpha_for_bits,
    dequantize_ref,
    influence_ref,
    normalize_rows_ref,
    quantize,
    quantize_absmax_ref,
    quantize_absmean_ref,
    quantize_sign_ref,
)

SETTINGS = dict(deadline=None, max_examples=20, print_blob=True)


def _rand(rng, n, k, scale=1.0):
    return (rng.standard_normal((n, k)) * scale).astype(np.float32)


# ---------------------------------------------------------------------------
# quantize kernel vs oracle
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    bits=st.sampled_from([2, 4, 8]),
    mode=st.sampled_from(["absmax", "absmean"]),
    rows=st.sampled_from([1, 2, 4]),
    k=st.sampled_from([8, 64, 256]),
    scale=st.floats(1e-3, 1e3),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantize_matches_ref(bits, mode, rows, k, scale, seed):
    block = 4
    g = _rand(np.random.default_rng(seed), rows * block, k, scale)
    codes, scales = quantize_pallas(jnp.array(g), bits=bits, mode=mode, block=block)
    fn = quantize_absmax_ref if mode == "absmax" else quantize_absmean_ref
    codes_ref, scales_ref = fn(jnp.array(g), alpha_for_bits(bits))
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(codes_ref))
    np.testing.assert_allclose(np.asarray(scales), np.asarray(scales_ref), rtol=1e-6)


@settings(**SETTINGS)
@given(
    rows=st.sampled_from([1, 3]),
    k=st.sampled_from([16, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sign_kernel_matches_ref(rows, k, seed):
    block = 8
    g = _rand(np.random.default_rng(seed), rows * block, k)
    codes, scales = quantize_pallas(jnp.array(g), bits=1, block=block)
    codes_ref, scales_ref = quantize_sign_ref(jnp.array(g))
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(codes_ref))
    np.testing.assert_allclose(np.asarray(scales), np.asarray(scales_ref), rtol=1e-6)


@settings(**SETTINGS)
@given(
    bits=st.sampled_from([2, 4, 8]),
    mode=st.sampled_from(["absmax", "absmean"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_codes_bounded_by_alpha(bits, mode, seed):
    g = _rand(np.random.default_rng(seed), 16, 64, 10.0)
    codes, _ = quantize_pallas(jnp.array(g), bits=bits, mode=mode, block=16)
    a = alpha_for_bits(bits)
    assert np.abs(np.asarray(codes)).max() <= a


def test_absmax_hits_outer_bin_exactly():
    # The row max must map to ±α exactly (paper Eq. 5 with g=S).
    g = np.zeros((4, 8), np.float32)
    g[:, 0] = [1.0, -2.0, 0.5, 100.0]
    codes, scales = quantize_pallas(jnp.array(g), bits=4, block=4)
    a = int(alpha_for_bits(4))
    np.testing.assert_array_equal(np.asarray(codes)[:, 0], [a, -a, a, a])
    np.testing.assert_allclose(np.asarray(scales), np.abs(g[:, 0]) / a, rtol=1e-6)


def test_sign_has_no_zero_bin():
    g = _rand(np.random.default_rng(0), 8, 32)
    codes, _ = quantize_pallas(jnp.array(g), bits=1, block=8)
    assert set(np.unique(np.asarray(codes))) <= {-1, 1}


def test_zero_rows_are_safe():
    g = np.zeros((4, 16), np.float32)
    for bits in (1, 2, 4, 8):
        codes, scales = quantize_pallas(jnp.array(g), bits=bits, block=4)
        assert np.isfinite(np.asarray(scales)).all()
        if bits > 1:
            assert (np.asarray(codes) == 0).all()
            np.testing.assert_array_equal(np.asarray(scales), 0.0)


@settings(**SETTINGS)
@given(bits=st.sampled_from([2, 4, 8]), seed=st.integers(0, 2**31 - 1))
def test_absmean_denser_than_absmax_at_low_bits(bits, seed):
    """Paper Fig. 3: absmean occupies the zero bin less than absmax."""
    g = _rand(np.random.default_rng(seed), 32, 256)
    qmax, _ = quantize_absmax_ref(jnp.array(g), alpha_for_bits(bits))
    qmean, _ = quantize_absmean_ref(jnp.array(g), alpha_for_bits(bits))
    zmax = (np.asarray(qmax) == 0).mean()
    zmean = (np.asarray(qmean) == 0).mean()
    assert zmean <= zmax + 1e-9


def test_dequantize_roundtrip_8bit_accuracy():
    g = _rand(np.random.default_rng(1), 16, 256)
    codes, scales = quantize_absmax_ref(jnp.array(g), alpha_for_bits(8))
    rec = dequantize_ref(codes, scales)
    err = np.abs(np.asarray(rec) - g).max() / np.abs(g).max()
    assert err < 0.01  # 8-bit absmax: ≤ 0.5/127 relative to row max


# ---------------------------------------------------------------------------
# influence kernel vs oracle
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    ti=st.sampled_from([1, 2, 4]),
    tj=st.sampled_from([1, 3]),
    k=st.sampled_from([16, 128, 512]),
    seed=st.integers(0, 2**31 - 1),
)
def test_influence_matches_ref(ti, tj, k, seed):
    bq, bv = 8, 4
    rng = np.random.default_rng(seed)
    qt = _rand(rng, ti * bq, k)
    qv = _rand(rng, tj * bv, k)
    out = influence_pallas(jnp.array(qt), jnp.array(qv), bq=bq, bv=bv)
    ref = influence_ref(jnp.array(qt), jnp.array(qv))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_influence_is_cosine_bounded(seed):
    rng = np.random.default_rng(seed)
    out = influence_pallas(
        jnp.array(_rand(rng, 16, 64)), jnp.array(_rand(rng, 8, 64)), bq=16, bv=8
    )
    assert np.abs(np.asarray(out)).max() <= 1.0 + 1e-5


def test_influence_self_similarity_is_one():
    g = _rand(np.random.default_rng(2), 8, 64)
    out = influence_pallas(jnp.array(g), jnp.array(g), bq=8, bv=8)
    np.testing.assert_allclose(np.diag(np.asarray(out)), 1.0, atol=1e-5)


def test_influence_zero_rows_give_zero():
    qt = np.zeros((8, 64), np.float32)
    qv = _rand(np.random.default_rng(3), 8, 64)
    out = influence_pallas(jnp.array(qt), jnp.array(qv), bq=8, bv=8)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_influence_scale_invariance():
    """The quantization scale cancels (QLESS stores it, scorer ignores it)."""
    rng = np.random.default_rng(4)
    qt = _rand(rng, 8, 64)
    qv = _rand(rng, 8, 64)
    a = influence_pallas(jnp.array(qt), jnp.array(qv), bq=8, bv=8)
    b = influence_pallas(jnp.array(qt * 37.5), jnp.array(qv * 0.001), bq=8, bv=8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_influence_int8_codes_match_float_path():
    """Scoring quantized int8 codes == scoring their float dequantization."""
    rng = np.random.default_rng(5)
    g = _rand(rng, 8, 64)
    codes, _ = quantize_absmax_ref(jnp.array(g), alpha_for_bits(8))
    a = influence_pallas(codes.astype(jnp.float32), jnp.array(g), bq=8, bv=8)
    b = influence_ref(codes, jnp.array(g))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# scheme dispatch mirror
# ---------------------------------------------------------------------------


def test_quantize_dispatch_16bit_is_identity():
    g = jnp.array(_rand(np.random.default_rng(6), 4, 16))
    out, scales = quantize(g, "absmax", 16)
    assert scales is None
    np.testing.assert_array_equal(np.asarray(out), np.asarray(g))


def test_quantize_dispatch_rejects_unknown_scheme():
    g = jnp.ones((2, 4))
    with pytest.raises(ValueError):
        quantize(g, "weird", 4)


def test_alpha_values():
    assert [alpha_for_bits(b) for b in (2, 4, 8)] == [1.0, 7.0, 127.0]
    with pytest.raises(ValueError):
        alpha_for_bits(1)


def test_normalize_rows_zero_safe():
    x = jnp.zeros((3, 5))
    np.testing.assert_array_equal(np.asarray(normalize_rows_ref(x)), 0.0)
