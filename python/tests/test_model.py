"""L2 correctness: SimLM forward/backward, per-sample grads, train step."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.rng import rademacher_projection
from compile.simconfig import CONFIGS, TINY, VOCAB_SIZE

CFG = TINY
S = CFG.seq


@pytest.fixture(scope="module")
def params():
    key = jax.random.PRNGKey(0)
    base = model.init_base_flat(CFG, key)
    lora = model.init_lora_flat(CFG, jax.random.PRNGKey(1))
    return base, lora


def _batch(b, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(4, VOCAB_SIZE, size=(b, S)).astype(np.int32)
    mask = np.zeros((b, S), np.float32)
    mask[:, S // 2:] = 1.0  # answer span = second half
    return jnp.array(toks), jnp.array(mask)


def test_flat_sizes_match_config(params):
    base, lora = params
    assert base.shape == (CFG.d_base,)
    assert lora.shape == (CFG.d_lora,)


def test_forward_shape_and_finite(params):
    base, lora = params
    toks, _ = _batch(1)
    logits = model.forward(CFG, base, lora, toks[0])
    assert logits.shape == (S, VOCAB_SIZE)
    assert bool(jnp.isfinite(logits).all())


def test_lora_starts_as_noop(params):
    """B=0 at init ⇒ adapters contribute nothing ⇒ logits == base model."""
    base, lora = params
    toks, _ = _batch(1)
    a = model.forward(CFG, base, lora, toks[0])
    b = model.forward(CFG, base, jnp.zeros_like(lora), toks[0])
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_lora_changes_forward_when_nonzero(params):
    base, lora = params
    toks, _ = _batch(1)
    a = model.forward(CFG, base, lora, toks[0])
    lora2 = lora + 0.05
    b = model.forward(CFG, base, lora2, toks[0])
    assert float(jnp.abs(a - b).max()) > 1e-4


def test_loss_positive_and_masked(params):
    base, lora = params
    toks, mask = _batch(1)
    loss = model.sample_loss(CFG, lora, base, toks[0], mask[0])
    assert float(loss) > 0
    # empty mask → 0/maximum(0,1) = 0, finite
    zloss = model.sample_loss(CFG, lora, base, toks[0], jnp.zeros(S))
    assert float(zloss) == 0.0


def test_loss_mask_excludes_prompt(params):
    """Changing prompt-only tokens must not change the (teacher-forced) loss
    contribution of answer tokens whose context is unchanged — but changing
    answer tokens must change the loss."""
    base, lora = params
    toks, mask = _batch(1, seed=3)
    l0 = model.sample_loss(CFG, lora, base, toks[0], mask[0])
    toks2 = toks.at[0, S - 1].set((int(toks[0, S - 1]) - 4 + 1) % 60 + 4)
    l1 = model.sample_loss(CFG, lora, base, toks2[0], mask[0])
    assert abs(float(l0) - float(l1)) > 1e-7


def test_train_step_decreases_loss(params):
    base, lora = params
    toks, mask = _batch(CFG.batch_train, seed=1)
    m = jnp.zeros_like(lora)
    v = jnp.zeros_like(lora)
    step = jax.jit(functools.partial(model.train_step, CFG))
    losses = []
    for t in range(1, 13):
        lora, m, v, loss = step(base, lora, m, v, float(t), toks, mask, 5e-3)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, losses


def test_per_sample_grads_match_individual(params):
    """vmapped per-sample SGD grads == stacked single-sample grads."""
    base, lora = params
    toks, mask = _batch(3, seed=2)
    proj = jnp.eye(CFG.d_lora, CFG.proj_dim)  # truncation "projection"
    feats = model.grad_val_features(CFG, base, lora, toks, mask, proj)
    for i in range(3):
        g = jax.grad(model.sample_loss, argnums=1)(CFG, lora, base, toks[i], mask[i])
        np.testing.assert_allclose(
            np.asarray(feats[i]), np.asarray(g[: CFG.proj_dim]), rtol=2e-4, atol=2e-5
        )


def test_grad_train_is_adam_direction(params):
    """With m=v=0, t=1: Γ = g/(√(g²·bias) + eps) elementwise — check against
    a direct computation."""
    base, lora = params
    toks, mask = _batch(2, seed=4)
    proj = jnp.eye(CFG.d_lora, CFG.proj_dim)
    m = jnp.zeros(CFG.d_lora)
    v = jnp.zeros(CFG.d_lora)
    t = 1.0
    feats = model.grad_train_features(CFG, base, lora, m, v, t, toks, mask, proj)
    from compile.simconfig import ADAM_B1, ADAM_B2, ADAM_EPS

    g = jax.grad(model.sample_loss, argnums=1)(CFG, lora, base, toks[0], mask[0])
    mhat = (1 - ADAM_B1) * g / (1 - ADAM_B1**t)
    vhat = (1 - ADAM_B2) * g * g / (1 - ADAM_B2**t)
    gamma = mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    np.testing.assert_allclose(
        np.asarray(feats[0]), np.asarray(gamma[: CFG.proj_dim]), rtol=2e-3, atol=2e-4
    )


def test_grad_features_projection_consistency(params):
    """Projecting with the Rademacher R == explicit matmul with rng.py's R."""
    base, lora = params
    toks, mask = _batch(2, seed=5)
    r = jnp.array(rademacher_projection(7, CFG.d_lora, CFG.proj_dim))
    feats = model.grad_val_features(CFG, base, lora, toks, mask, r)
    g = jax.vmap(jax.grad(model.sample_loss, argnums=1), in_axes=(None, None, None, 0, 0))(
        CFG, lora, base, toks, mask
    )
    np.testing.assert_allclose(
        np.asarray(feats), np.asarray(g @ r), rtol=1e-4, atol=1e-5
    )


def test_loss_eval_matches_sample_loss(params):
    base, lora = params
    toks, mask = _batch(CFG.batch_eval, seed=6)
    nll = model.loss_eval(CFG, base, lora, toks, mask)
    assert nll.shape == (CFG.batch_eval,)
    one = model.sample_loss(CFG, lora, base, toks[0], mask[0])
    np.testing.assert_allclose(float(nll[0]), float(one), rtol=1e-5)


def test_decode_step_matches_forward(params):
    base, lora = params
    toks, _ = _batch(CFG.batch_eval, seed=7)
    pos = jnp.full((CFG.batch_eval,), 10, jnp.int32)
    logits = model.decode_step(CFG, base, lora, toks, pos)
    assert logits.shape == (CFG.batch_eval, VOCAB_SIZE)
    full = model.forward(CFG, base, lora, toks[0])
    np.testing.assert_allclose(np.asarray(logits[0]), np.asarray(full[10]), rtol=1e-4, atol=1e-5)


def test_decode_step_respects_causality(params):
    """Logits at pos must not depend on tokens after pos."""
    base, lora = params
    toks, _ = _batch(2, seed=8)
    pos = jnp.array([20, 20], jnp.int32)
    a = model.decode_step(CFG, base, lora, toks, pos)
    toks2 = toks.at[:, 40:].set(5)
    b = model.decode_step(CFG, base, lora, toks2, pos)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_all_config_shapes_consistent():
    for name, cfg in CONFIGS.items():
        assert cfg.d_model % cfg.n_heads == 0, name
        assert cfg.d_lora == cfg.n_layers * 4 * 2 * cfg.d_model * cfg.lora_rank
        base = sum(
            int(np.prod(s)) for _, s in cfg.base_shapes()
        )
        assert base == cfg.d_base
